//! `HttpBackend` — a [`Backend`] implementation that speaks the gateway
//! protocol over real sockets.
//!
//! The mirror image of [`super::server`]: every trait method becomes one
//! HTTP request (ranged reads of length zero become a HEAD — HTTP cannot
//! spell an empty byte range — with the clamp check applied locally,
//! which is observationally identical). Connections are pooled and
//! reused (HTTP/1.1 keep-alive).
//!
//! **Every send failure is retryable.** GET/HEAD are idempotent by
//! nature; each mutating request (`PUT`/`POST`/`DELETE`) is stamped
//! with a unique `x-request-id` drawn from this backend's seeded PCG32
//! stream and keeps that id across re-sends, so the gateway's replay
//! cache answers a duplicate with the *original* response instead of
//! re-executing. That turns "connection died mid-response" — killed,
//! truncated, stalled past [`CLIENT_READ_TIMEOUT`], or reset sockets —
//! from a fatal ambiguity into a blind re-send inside a bounded per-op
//! budget ([`MAX_SEND_RETRIES`] attempts) with exponential backoff and
//! decorrelated jitter. [`HttpBackend::retried_sends`] counts re-sends;
//! [`HttpBackend::replayed_responses`] counts cache-answered duplicates
//! (each one a mid-response failure recovered without re-execution).
//!
//! Name-bearing errors are reconstructed from the response's
//! `x-error-kind` plus the *caller's* names, so a `NoSuchKey` from a
//! remote gateway is byte-identical to one from an in-process backend —
//! including when a [`namespace`](HttpBackend::connect) prefixes
//! container names on the wire (the HTTP analogue of the `fs` backend's
//! unique per-run subdirectory).

//! Server-side backpressure — a real `429 Too Many Requests` from the
//! gateway's token-bucket limiter, or a `503 over-capacity` shed at the
//! connection cap — is absorbed *below* the `Backend` trait: both are
//! written before the request executes, so the client sleeps out the
//! server's `Retry-After` and blindly re-sends within a bounded budget.
//! Callers above the trait (the store front end, the stress workers)
//! see identical op counts and results whether the gateway throttles or
//! not; [`HttpBackend::throttled_429s`]/[`HttpBackend::shed_503s`]
//! count what was absorbed.

use super::encoding::{encode_query, meta_header, pct_decode, pct_encode};
use super::http::{
    read_response, write_request, Headers, Response, REQUEST_ID, REQUEST_REPLAYED,
    STALE_CONNECTION,
};
use super::server::classify_op;
use crate::objectstore::backend::{
    clamp_range, AssembledUpload, Backend, BackendError, ListPage, ObjectStat,
};
use crate::objectstore::container::ObjectSummary;
use crate::objectstore::object::{Metadata, Object};
use crate::simclock::SimInstant;
use crate::util::rng::Pcg32;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A `Backend` over the gateway's REST protocol. `Send + Sync`; safe to
/// share across executor threads (each request takes a pooled
/// connection for its duration).
pub struct HttpBackend {
    addr: String,
    /// Optional container namespace: `c` travels as `{ns}.{c}`.
    ns: Option<String>,
    /// Bearer token sent as `Authorization` on every request.
    token: Option<String>,
    /// Idle keep-alive connections, at most [`MAX_POOLED_IDLE`].
    pool: Mutex<Vec<TcpStream>>,
    /// Request-id and retry-jitter stream. Reseedable via
    /// [`HttpBackend::with_rng_seed`]; the default stream is unique per
    /// backend instance (time ⊕ pid seed, per-process stream counter)
    /// because the gateway replay cache is keyed by id alone — two
    /// clients drawing the same ids would replay each other's responses.
    rng: Mutex<Pcg32>,
    /// `429`s absorbed by the backpressure retry loop.
    throttled: AtomicU64,
    /// Over-capacity `503`s absorbed by the backpressure retry loop.
    shed: AtomicU64,
    /// Wire-level re-sends after send failures (the chaos-recovery path).
    retried: AtomicU64,
    /// Responses answered from the gateway's replay cache.
    replayed: AtomicU64,
    /// Completed wire operations per [`OpKind`] (`OpKind::ALL` order),
    /// classified with the gateway's own routing table. One logical
    /// operation counts once no matter how many backpressure or wire
    /// re-sends it took — so on a chaos-free run these totals must
    /// equal the server's executed-op counters exactly (the
    /// `stress --scrape` gate).
    wire_ops: [AtomicU64; 7],
}

/// Most blind re-sends after backpressure rejections before the
/// rejection surfaces to the caller as an error.
const MAX_BACKPRESSURE_RETRIES: u32 = 32;
/// Total wall-clock sleep budget across one request's backpressure
/// retries.
const MAX_BACKPRESSURE_WAIT: Duration = Duration::from_secs(30);
/// Cap on a single `Retry-After` sleep, so a hostile header cannot
/// park a worker for minutes.
const MAX_RETRY_AFTER_SECS: f64 = 5.0;

/// Per-operation wire retry budget: re-sends after send failures
/// (distinct from the backpressure budget above, which absorbs polite
/// server rejections rather than a broken wire).
pub const MAX_SEND_RETRIES: u32 = 8;
/// Floor of the decorrelated-jitter retry pause.
const RETRY_BASE: Duration = Duration::from_millis(5);
/// Cap on any single retry pause.
const RETRY_CAP: Duration = Duration::from_millis(250);
/// How long a response read may block before the client declares the
/// response dead and re-sends. Deliberately shorter than the server's
/// chaos `stall` hold (`gateway::config::STALL_HOLD`, 3s) so a stalled
/// response times out *here* and exercises the blind-re-send path.
pub(crate) const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// The request-id / jitter stream used when the caller does not reseed:
/// unique per backend instance, across processes sharing one gateway.
fn unique_rng() -> Pcg32 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = u64::from(std::process::id());
    Pcg32::with_stream(nanos ^ (pid << 32), SEQ.fetch_add(1, Ordering::Relaxed))
}

/// The server's `Retry-After`, parsed as (possibly fractional)
/// delta-seconds per RFC 9110; a missing or unparseable header falls
/// back to a small flat pause.
fn retry_after(resp: &Response) -> Duration {
    let secs = resp
        .headers
        .get("retry-after")
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|s| s.is_finite() && *s >= 0.0)
        .unwrap_or(0.05);
    Duration::from_secs_f64(secs.min(MAX_RETRY_AFTER_SECS))
}

/// Cap on idle pooled connections per backend. Under a concurrency
/// burst every in-flight request holds its own connection (unbounded by
/// design — the server is thread-per-connection), but once the burst
/// drains only this many sockets are kept; the rest close on drop
/// instead of accumulating as idle fds for the life of the backend.
pub const MAX_POOLED_IDLE: usize = 8;

fn io_err(ctx: &str, e: std::io::Error) -> BackendError {
    BackendError::Io(format!("http backend {ctx}: {e}"))
}

/// A failed exchange, tagged with whether the failure proves the server
/// never executed the request:
///
/// * a **write-side** failure — the request never fully reached the
///   server, so it cannot have been parsed, let alone executed;
/// * EOF **before any response byte** ([`STALE_CONNECTION`]) — the
///   gateway answers every request it parses, so a connection that
///   closed without a single byte never processed one.
///
/// A failure while reading a partially received response gives no such
/// guarantee — the request may well have executed. Those used to be
/// terminal ("several requests are not idempotent"); now they are
/// retried too, because the request-id replay protocol makes the blind
/// re-send exact (see [`super::config::ReplayCache`]). The tag still
/// matters for pacing: a provably-unexecuted failure on a pooled
/// connection is routine keep-alive staleness and retries immediately,
/// everything else backs off first.
struct SendFailure {
    retry_safe: bool,
    error: std::io::Error,
}

impl HttpBackend {
    /// Connect to a gateway at `addr` (`host:port`, with an optional
    /// `http://` prefix). `ns`, when given, prefixes every container
    /// name on the wire so independent clients of one served store get
    /// disjoint worlds. Fails fast if the gateway is unreachable.
    pub fn connect(addr: &str, ns: Option<String>) -> Result<Self, BackendError> {
        let addr = addr.trim_start_matches("http://").trim_end_matches('/');
        if !addr.contains(':') {
            return Err(BackendError::Io(format!(
                "http backend address '{addr}' must be HOST:PORT"
            )));
        }
        let probe = TcpStream::connect(addr).map_err(|e| io_err("connect", e))?;
        let _ = probe.set_read_timeout(Some(CLIENT_READ_TIMEOUT));
        Ok(Self {
            addr: addr.to_string(),
            ns,
            token: None,
            pool: Mutex::new(vec![probe]),
            rng: Mutex::new(unique_rng()),
            throttled: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            replayed: AtomicU64::new(0),
            wire_ops: Default::default(),
        })
    }

    /// Attach a bearer token, sent as `Authorization: Bearer <token>` on
    /// every request (required when the gateway runs with `auth_token`).
    pub fn with_token(mut self, token: impl Into<String>) -> Self {
        self.token = Some(token.into());
        self
    }

    /// Reseed the request-id / retry-jitter stream, making the id
    /// sequence deterministic (the stress workers derive this from the
    /// run seed, worker id, and run namespace). Seeds MUST be distinct
    /// across clients that share a gateway: the replay cache is keyed
    /// by id alone, so colliding streams would replay each other's
    /// responses.
    pub fn with_rng_seed(mut self, seed: u64) -> Self {
        self.rng = Mutex::new(Pcg32::new(seed));
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// `429`s absorbed (slept out and re-sent) by this backend.
    pub fn throttled_429s(&self) -> u64 {
        self.throttled.load(Ordering::Relaxed)
    }

    /// Over-capacity `503`s absorbed by this backend.
    pub fn shed_503s(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Wire-level re-sends after send failures (killed, truncated,
    /// stalled, or reset connections; refused connects). Visibly
    /// nonzero under `--chaos`; normally zero on a healthy wire.
    pub fn retried_sends(&self) -> u64 {
        self.retried.load(Ordering::Relaxed)
    }

    /// Responses answered from the gateway's replay cache — each one a
    /// mutating request whose first response died mid-flight and whose
    /// blind re-send was recovered *without* re-execution.
    pub fn replayed_responses(&self) -> u64 {
        self.replayed.load(Ordering::Relaxed)
    }

    /// Completed wire operations by [`crate::metrics::OpKind`] index
    /// (`OpKind::ALL` order). The client-side half of the scrape gate:
    /// chaos-free, these equal the gateway's executed-op counters.
    pub fn wire_op_counts(&self) -> [u64; 7] {
        std::array::from_fn(|i| self.wire_ops[i].load(Ordering::Relaxed))
    }

    /// Count one completed logical operation against the gateway's own
    /// classification table. Called once per [`HttpBackend::request`]
    /// that came back with a real (non-backpressure) response —
    /// rejections the budget could not absorb and dead-wire errors are
    /// *not* ops, exactly as the server sees them.
    fn record_wire_op(&self, method: &str, target: &str) {
        let (path, query) = target.split_once('?').unwrap_or((target, ""));
        if let Some(kind) = classify_op(method, path, query) {
            self.wire_ops[kind.index()].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A fresh 128-bit idempotency id from this backend's seeded PCG32
    /// stream. One id covers every wire re-send of one logical
    /// operation — that equality is what the replay cache keys on.
    fn fresh_request_id(&self) -> String {
        let mut rng = self.rng.lock().unwrap();
        format!("{:016x}{:016x}", rng.next_u64(), rng.next_u64())
    }

    /// Sleep out one decorrelated-jitter pause and return it (the seed
    /// of the next draw): `min(cap, uniform(base, 3 × prev))`, so
    /// concurrent clients retrying against a sick gateway spread out
    /// instead of re-sending in lockstep.
    fn backoff(&self, prev: Duration) -> Duration {
        let base = RETRY_BASE.as_secs_f64();
        let hi = (prev.as_secs_f64() * 3.0).max(base);
        let draw = {
            let mut rng = self.rng.lock().unwrap();
            base + rng.next_f64() * (hi - base)
        };
        let pause = Duration::from_secs_f64(draw.min(RETRY_CAP.as_secs_f64()));
        std::thread::sleep(pause);
        pause
    }

    fn wire_container(&self, container: &str) -> String {
        match &self.ns {
            Some(ns) => format!("{ns}.{container}"),
            None => container.to_string(),
        }
    }

    fn strip_ns(&self, wire: &str) -> String {
        match &self.ns {
            Some(ns) => wire
                .strip_prefix(&format!("{ns}."))
                .unwrap_or(wire)
                .to_string(),
            None => wire.to_string(),
        }
    }

    fn object_target(&self, container: &str, key: &str) -> String {
        format!(
            "/v1/{}/{}",
            pct_encode(&self.wire_container(container)),
            pct_encode(key)
        )
    }

    fn container_target(&self, container: &str) -> String {
        format!("/v1/{}", pct_encode(&self.wire_container(container)))
    }

    /// Issue one request, absorbing server-side backpressure: a `429`
    /// (token bucket drained) or an over-capacity `503` (shed at the
    /// connection cap) is written *before* the request executes, so the
    /// client sleeps out the server's `Retry-After` and re-sends —
    /// blindly, for every verb — within a bounded budget. Past the
    /// budget the rejection is returned and the caller maps it to an
    /// error. Any other response passes through untouched.
    ///
    /// Mutating verbs are stamped with one `x-request-id` *here*, above
    /// both retry loops, so every re-send — wire-failure or
    /// backpressure — carries the same id and the gateway can recognize
    /// a duplicate of an already-executed request.
    fn request(
        &self,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, BackendError> {
        let stamped;
        let headers = if matches!(method, "PUT" | "POST" | "DELETE") {
            let mut h = headers.clone();
            h.push(REQUEST_ID, self.fresh_request_id());
            stamped = h;
            &stamped
        } else {
            headers
        };
        let mut attempts = 0u32;
        let mut waited = Duration::ZERO;
        loop {
            let resp = self.exchange(method, target, headers, body)?;
            let backpressure = resp.status == 429
                || (resp.status == 503
                    && resp.headers.get("x-error-kind") == Some("over-capacity"));
            if !backpressure {
                // The request executed (404s included, like the
                // server's accounting); rejections returned past the
                // budget below never did.
                self.record_wire_op(method, target);
                return Ok(resp);
            }
            let pause = retry_after(&resp);
            attempts += 1;
            if attempts > MAX_BACKPRESSURE_RETRIES || waited + pause > MAX_BACKPRESSURE_WAIT {
                return Ok(resp);
            }
            if resp.status == 429 {
                self.throttled.fetch_add(1, Ordering::Relaxed);
            } else {
                self.shed.fetch_add(1, Ordering::Relaxed);
            }
            std::thread::sleep(pause);
            waited += pause;
        }
    }

    /// One wire exchange, reusing a pooled connection when available,
    /// retrying *any* send failure within [`MAX_SEND_RETRIES`]. The
    /// blind re-send is sound because every request this client
    /// produces is either naturally idempotent (`GET`/`HEAD`) or
    /// carries an `x-request-id` the gateway's replay cache answers
    /// duplicates from — so a re-send of an already-executed `initiate`
    /// cannot leak a second upload, nor a re-sent `create_container`
    /// turn into a spurious 409. The explicit check stays to document
    /// that argument and to fail closed on any future unstamped
    /// mutating verb.
    fn exchange(
        &self,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, BackendError> {
        let authed;
        let headers = match &self.token {
            None => headers,
            Some(token) => {
                let mut h = headers.clone();
                h.push("Authorization", format!("Bearer {token}"));
                authed = h;
                &authed
            }
        };
        let replay_protected =
            headers.get(REQUEST_ID).is_some() || matches!(method, "GET" | "HEAD");
        let mut attempts = 0u32;
        let mut pause = RETRY_BASE;
        loop {
            let (stream, reused) = match self.pool.lock().unwrap().pop() {
                Some(s) => (s, true),
                None => match TcpStream::connect(&self.addr) {
                    Ok(s) => {
                        let _ = s.set_read_timeout(Some(CLIENT_READ_TIMEOUT));
                        (s, false)
                    }
                    Err(error) => {
                        // A refused connect is provably unexecuted; it
                        // shares the attempt budget and backoff (the
                        // gateway may be mid-restart).
                        attempts += 1;
                        if attempts > MAX_SEND_RETRIES {
                            return Err(io_err("connect", error));
                        }
                        self.retried.fetch_add(1, Ordering::Relaxed);
                        pause = self.backoff(pause);
                        continue;
                    }
                },
            };
            match self.send_on(stream, method, target, headers, body) {
                Ok(resp) => {
                    if resp.headers.get(REQUEST_REPLAYED) == Some("true") {
                        self.replayed.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(resp);
                }
                Err(f) => {
                    attempts += 1;
                    if (!f.retry_safe && !replay_protected) || attempts > MAX_SEND_RETRIES {
                        return Err(io_err("request", f.error));
                    }
                    self.retried.fetch_add(1, Ordering::Relaxed);
                    // A provably-unexecuted failure on a pooled
                    // connection is routine keep-alive staleness: go
                    // again immediately on a fresh socket. Anything
                    // else looks like a sick wire — back off first.
                    if !(reused && f.retry_safe) {
                        pause = self.backoff(pause);
                    }
                }
            }
        }
    }

    fn send_on(
        &self,
        stream: TcpStream,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, SendFailure> {
        let mut write_half = stream.try_clone().map_err(|error| SendFailure {
            retry_safe: true,
            error,
        })?;
        // Write-side failures are retry-safe: the request never fully
        // left, so the server cannot have parsed (or executed) it.
        write_request(&mut write_half, method, target, headers, body).map_err(|error| {
            SendFailure {
                retry_safe: true,
                error,
            }
        })?;
        let mut reader = BufReader::new(stream);
        let resp = read_response(&mut reader).map_err(|error| SendFailure {
            // Read-side: only EOF before any response byte proves the
            // request was never processed.
            retry_safe: error.kind() == std::io::ErrorKind::UnexpectedEof
                && error.to_string() == STALE_CONNECTION,
            error,
        })?;
        // The whole body was consumed; the connection is reusable —
        // but only up to the idle cap: beyond it, dropping the stream
        // closes the socket and the pool stops growing.
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < MAX_POOLED_IDLE {
            pool.push(reader.into_inner());
        }
        Ok(resp)
    }

    /// Current idle pooled connections (test/diagnostic hook).
    pub fn pooled_idle(&self) -> usize {
        self.pool.lock().unwrap().len()
    }

    /// Rebuild the exact [`BackendError`] from a gateway error response,
    /// using the caller's (un-namespaced) names.
    fn decode_error(
        &self,
        resp: &Response,
        container: &str,
        key: &str,
        upload_id: u64,
    ) -> BackendError {
        let msg = || {
            resp.headers
                .get("x-error-msg")
                .and_then(pct_decode)
                .unwrap_or_else(|| format!("HTTP {}", resp.status))
        };
        match resp.headers.get("x-error-kind") {
            Some("no-such-container") => BackendError::NoSuchContainer(container.to_string()),
            Some("no-such-key") => BackendError::no_such_key(container, key),
            Some("container-exists") => {
                BackendError::ContainerAlreadyExists(container.to_string())
            }
            Some("no-such-upload") => BackendError::NoSuchUpload(upload_id),
            Some("invalid-request") => BackendError::InvalidRequest(msg()),
            Some("invalid-range") => {
                // Rebuilt below by the ranged-read path (it knows the
                // offset); elsewhere surface the raw message.
                BackendError::InvalidRange(msg())
            }
            Some("io") => BackendError::Io(msg()),
            Some("unauthorized") => {
                BackendError::Io("gateway auth: 401 unauthorized (missing bearer token)".into())
            }
            Some("forbidden") => {
                BackendError::Io("gateway auth: 403 forbidden (bearer token rejected)".into())
            }
            Some("throttled") => BackendError::Io(
                "gateway throttled: 429 persisted past the client retry budget".into(),
            ),
            Some("over-capacity") => BackendError::Io(
                "gateway over capacity: 503 persisted past the client retry budget".into(),
            ),
            _ => BackendError::Io(format!(
                "unexpected gateway response: HTTP {} for {container}/{key}",
                resp.status
            )),
        }
    }

    fn meta_headers(metadata: &Metadata) -> Headers {
        let mut headers = Headers::new();
        for (k, v) in metadata {
            let (name, value) = meta_header(k, v);
            headers.push(name, value);
        }
        headers
    }

    /// Decode the stat carried on an object response's headers.
    fn decode_stat(resp: &Response) -> Result<ObjectStat, BackendError> {
        let etag = resp
            .headers
            .get("etag")
            .map(|v| v.trim_matches('"'))
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(|| BackendError::Io("gateway response missing ETag".into()))?;
        let size: u64 = resp
            .headers
            .get("x-object-size")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| BackendError::Io("gateway response missing x-object-size".into()))?;
        let created_at = SimInstant(
            resp.headers
                .get("x-sim-created-at")
                .and_then(|v| v.parse().ok())
                .unwrap_or(0),
        );
        Ok(ObjectStat {
            size,
            etag,
            metadata: Self::decode_meta(resp)?,
            created_at,
        })
    }

    fn decode_meta(resp: &Response) -> Result<Metadata, BackendError> {
        let mut md = Metadata::new();
        for (k, v) in resp.headers.with_prefix("x-object-meta-") {
            let (Some(k), Some(v)) = (pct_decode(k), pct_decode(v)) else {
                return Err(BackendError::Io("undecodable x-object-meta header".into()));
            };
            md.insert(k, v);
        }
        Ok(md)
    }
}

impl Backend for HttpBackend {
    fn name(&self) -> &'static str {
        "http"
    }

    fn create_container(&self, name: &str) -> Result<(), BackendError> {
        let resp = self.request("PUT", &self.container_target(name), &Headers::new(), b"")?;
        match resp.status {
            201 => Ok(()),
            _ => Err(self.decode_error(&resp, name, "", 0)),
        }
    }

    fn container_exists(&self, name: &str) -> bool {
        // Goes through the full safe-retry path (HEAD is idempotent, so
        // every send failure is re-sent within the wire budget) — a
        // single flaky connection can no longer make an existing
        // container look missing and skip `create_container`. The trait
        // still returns a bare bool, so if the gateway stays down past
        // the whole budget, warn loudly instead of letting a dead
        // gateway masquerade as a missing container (the very next
        // fallible operation surfaces the real I/O error).
        match self.request("HEAD", &self.container_target(name), &Headers::new(), b"") {
            Ok(resp) => resp.status == 200,
            Err(e) => {
                eprintln!("warning: http backend container_exists({name}): {e}");
                false
            }
        }
    }

    fn put(&self, container: &str, key: &str, obj: Object) -> Result<bool, BackendError> {
        let mut headers = Self::meta_headers(&obj.metadata);
        headers.push("x-sim-created-at", obj.created_at.0.to_string());
        let resp = self.request(
            "PUT",
            &self.object_target(container, key),
            &headers,
            &obj.data,
        )?;
        match resp.status {
            201 => Ok(resp.headers.get("x-replaced") == Some("true")),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn get(&self, container: &str, key: &str) -> Result<Object, BackendError> {
        let resp = self.request("GET", &self.object_target(container, key), &Headers::new(), b"")?;
        match resp.status {
            200 => {
                let stat = Self::decode_stat(&resp)?;
                Ok(Object {
                    data: Arc::new(resp.body),
                    metadata: stat.metadata,
                    created_at: stat.created_at,
                    etag: stat.etag,
                })
            }
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn get_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> Result<(Vec<u8>, ObjectStat), BackendError> {
        if len == 0 {
            // HTTP cannot spell an empty byte range; a HEAD plus the
            // shared clamp check is observationally identical (stat +
            // empty slice, or the 416 for offsets past EOF).
            let stat = self.head(container, key)?;
            clamp_range(container, key, offset, 0, stat.size)?;
            return Ok((Vec::new(), stat));
        }
        let mut headers = Headers::new();
        headers.push("Range", format!("bytes={}-{}", offset, offset + len - 1));
        let resp = self.request("GET", &self.object_target(container, key), &headers, b"")?;
        match resp.status {
            206 => {
                let stat = Self::decode_stat(&resp)?;
                Ok((resp.body, stat))
            }
            416 => {
                // Rebuild the clamp_range error exactly, from the
                // standard `Content-Range: bytes */SIZE` total.
                let size: u64 = resp
                    .headers
                    .get("content-range")
                    .and_then(|v| v.strip_prefix("bytes */"))
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(0);
                Err(clamp_range(container, key, offset, len, size)
                    .err()
                    .unwrap_or_else(|| {
                        BackendError::Io("gateway 416 for a satisfiable range".into())
                    }))
            }
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn head(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        let resp = self.request("HEAD", &self.object_target(container, key), &Headers::new(), b"")?;
        match resp.status {
            200 => Self::decode_stat(&resp),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn delete(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        let resp = self.request(
            "DELETE",
            &self.object_target(container, key),
            &Headers::new(),
            b"",
        )?;
        match resp.status {
            204 => Self::decode_stat(&resp),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn list_page(
        &self,
        container: &str,
        prefix: &str,
        start_after: Option<&str>,
        max_keys: usize,
    ) -> Result<ListPage, BackendError> {
        let mut params = vec![
            ("prefix", prefix.to_string()),
            ("limit", max_keys.to_string()),
        ];
        if let Some(marker) = start_after {
            params.push(("marker", marker.to_string()));
        }
        let target = format!("{}?{}", self.container_target(container), encode_query(&params));
        let resp = self.request("GET", &target, &Headers::new(), b"")?;
        if resp.status != 200 {
            return Err(self.decode_error(&resp, container, "", 0));
        }
        let text = String::from_utf8(resp.body)
            .map_err(|_| BackendError::Io("non-UTF-8 listing body".into()))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let mut cols = line.split(' ');
            let (Some(name_enc), Some(size_s), Some(etag_s), None) =
                (cols.next(), cols.next(), cols.next(), cols.next())
            else {
                return Err(BackendError::Io(format!("malformed listing line '{line}'")));
            };
            let (Some(name), Ok(size), Ok(etag)) = (
                pct_decode(name_enc),
                size_s.parse::<u64>(),
                u64::from_str_radix(etag_s, 16),
            ) else {
                return Err(BackendError::Io(format!("malformed listing line '{line}'")));
            };
            entries.push(ObjectSummary { name, size, etag });
        }
        let next = match resp.headers.get("x-next-marker") {
            None => None,
            Some(enc) => Some(
                pct_decode(enc)
                    .ok_or_else(|| BackendError::Io("undecodable x-next-marker".into()))?,
            ),
        };
        Ok(ListPage { entries, next })
    }

    fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> Result<u64, BackendError> {
        let headers = Self::meta_headers(&metadata);
        let target = format!("{}?uploads=", self.object_target(container, key));
        let resp = self.request("POST", &target, &headers, b"")?;
        match resp.status {
            200 => resp
                .headers
                .get("x-upload-id")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| BackendError::Io("gateway response missing x-upload-id".into())),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> Result<(), BackendError> {
        let target = format!("/v1-upload/{upload_id}/{part_number}");
        let resp = self.request("PUT", &target, &Headers::new(), &data)?;
        match resp.status {
            201 => Ok(()),
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn complete_multipart(
        &self,
        upload_id: u64,
        min_part_size: u64,
    ) -> Result<AssembledUpload, BackendError> {
        let target = format!("/v1-upload/{upload_id}?min-part-size={min_part_size}");
        let resp = self.request("POST", &target, &Headers::new(), b"")?;
        match resp.status {
            200 => {
                let container_wire = resp
                    .headers
                    .get("x-container")
                    .and_then(pct_decode)
                    .ok_or_else(|| BackendError::Io("gateway response missing x-container".into()))?;
                let key = resp
                    .headers
                    .get("x-key")
                    .and_then(pct_decode)
                    .ok_or_else(|| BackendError::Io("gateway response missing x-key".into()))?;
                Ok(AssembledUpload {
                    container: self.strip_ns(&container_wire),
                    key,
                    data: resp.body,
                    metadata: Self::decode_meta(&resp)?,
                })
            }
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn abort_multipart(&self, upload_id: u64) -> Result<(), BackendError> {
        let target = format!("/v1-upload/{upload_id}");
        let resp = self.request("DELETE", &target, &Headers::new(), b"")?;
        match resp.status {
            204 => Ok(()),
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn multipart_in_flight(&self) -> usize {
        self.request("GET", "/v1-upload", &Headers::new(), b"")
            .ok()
            .filter(|resp| resp.status == 200)
            .and_then(|resp| String::from_utf8(resp.body).ok())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }

    fn live_count(&self, container: &str) -> usize {
        self.live_stat(container, "count") as usize
    }

    fn live_bytes(&self, container: &str) -> u64 {
        self.live_stat(container, "bytes")
    }
}

impl HttpBackend {
    fn live_stat(&self, container: &str, which: &str) -> u64 {
        let target = format!("{}?live={which}", self.container_target(container));
        self.request("GET", &target, &Headers::new(), b"")
            .ok()
            .filter(|resp| resp.status == 200)
            .and_then(|resp| String::from_utf8(resp.body).ok())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::GatewayServer;
    use crate::objectstore::backend::ShardedMemBackend;

    #[test]
    fn retry_after_parses_fractional_integer_and_garbage() {
        let with = |v: &str| Response::new(429).with_header("Retry-After", v);
        assert_eq!(retry_after(&with("0.02")), Duration::from_secs_f64(0.02));
        assert_eq!(retry_after(&with("1")), Duration::from_secs(1));
        // Hostile values fall back or clamp instead of parking a worker.
        assert_eq!(retry_after(&with("soon")), Duration::from_secs_f64(0.05));
        assert_eq!(retry_after(&with("-3")), Duration::from_secs_f64(0.05));
        assert_eq!(
            retry_after(&with("99999")),
            Duration::from_secs_f64(MAX_RETRY_AFTER_SECS)
        );
        assert_eq!(retry_after(&Response::new(429)), Duration::from_secs_f64(0.05));
    }

    #[test]
    fn request_ids_are_deterministic_per_seed_and_unique_within_a_stream() {
        let server = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(1)))
            .expect("bind ephemeral");
        let handle = server.spawn();
        let addr = handle.addr().to_string();
        let connect = |seed| HttpBackend::connect(&addr, None).unwrap().with_rng_seed(seed);
        let ids = |b: &HttpBackend| -> Vec<String> {
            (0..64).map(|_| b.fresh_request_id()).collect()
        };
        let a = ids(&connect(42));
        assert_eq!(a, ids(&connect(42)), "same seed must draw the same id sequence");
        assert_ne!(a, ids(&connect(43)), "different seeds must diverge");
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "ids within one stream must be unique");
        assert!(a.iter().all(|id| id.len() == 32 && id.bytes().all(|b| b.is_ascii_hexdigit())));
        // The default (unseeded) streams of two backends also diverge.
        let d1 = HttpBackend::connect(&addr, None).unwrap();
        let d2 = HttpBackend::connect(&addr, None).unwrap();
        assert_ne!(ids(&d1), ids(&d2));
    }

    #[test]
    fn backoff_pauses_stay_inside_the_decorrelated_jitter_envelope() {
        let server = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(1)))
            .expect("bind ephemeral");
        let handle = server.spawn();
        let b = HttpBackend::connect(&handle.addr().to_string(), None)
            .unwrap()
            .with_rng_seed(7);
        let mut prev = RETRY_BASE;
        for _ in 0..12 {
            let next = b.backoff(prev);
            assert!(next >= RETRY_BASE, "pause {next:?} under the base");
            assert!(next <= RETRY_CAP, "pause {next:?} over the cap");
            let ceiling = Duration::from_secs_f64(
                (prev.as_secs_f64() * 3.0).max(RETRY_BASE.as_secs_f64()),
            );
            assert!(next <= ceiling.min(RETRY_CAP) + Duration::from_micros(1));
            prev = next;
        }
    }

    #[test]
    fn wire_op_counts_mirror_the_gateways_table() {
        let server = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(2)))
            .expect("bind ephemeral");
        let handle = server.spawn();
        let b = HttpBackend::connect(&handle.addr().to_string(), None).unwrap();
        b.create_container("res").unwrap(); // PUT container  → PUT Object class
        assert!(b.container_exists("res")); // HEAD container → HEAD Container
        b.put("res", "k", Object::new(b"x".to_vec(), Metadata::new(), SimInstant::EPOCH))
            .unwrap(); // → PUT Object
        b.get("res", "k").unwrap(); // → GET Object
        b.head("res", "k").unwrap(); // → HEAD Object
        let _ = b.live_count("res"); // ?live= debug route → not an op
        // An executed 404 is still an op, on both sides of the wire.
        assert!(b.get("res", "missing").is_err());
        // OpKind::ALL order: Head, Get, Put, Copy, Delete, GetC, HeadC.
        assert_eq!(b.wire_op_counts(), [1, 2, 2, 0, 0, 0, 1]);
    }

    #[test]
    fn idle_pool_is_capped_and_recovers_after_a_burst() {
        let server = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
            .expect("bind ephemeral");
        let handle = server.spawn();
        let b = Arc::new(HttpBackend::connect(&handle.addr().to_string(), None).unwrap());
        b.create_container("res").unwrap();
        // Exhaust: a burst far wider than the cap, every thread holding
        // a connection at once (a barrier forces the overlap, so the
        // pool is empty mid-burst and each thread opens its own socket).
        let n = 4 * MAX_POOLED_IDLE;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let threads: Vec<_> = (0..n)
            .map(|i| {
                let b = b.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let key = format!("k/{i}");
                    let obj = Object::new(vec![i as u8; 64], Metadata::new(), SimInstant::EPOCH);
                    b.put("res", &key, obj).unwrap();
                    assert_eq!(b.get("res", &key).unwrap().size(), 64);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Release: the burst drained; the pool kept at most the cap.
        assert!(
            b.pooled_idle() <= MAX_POOLED_IDLE,
            "pool grew to {} (> cap {MAX_POOLED_IDLE})",
            b.pooled_idle()
        );
        // Recover: the backend still serves requests afterwards.
        assert_eq!(b.live_count("res"), n);
        b.put("res", "after", Object::new(b"x".to_vec(), Metadata::new(), SimInstant::EPOCH))
            .unwrap();
        assert_eq!(&**b.get("res", "after").unwrap().data, b"x");
        assert!(b.pooled_idle() <= MAX_POOLED_IDLE);
    }
}
