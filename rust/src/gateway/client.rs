//! `HttpBackend` — a [`Backend`] implementation that speaks the gateway
//! protocol over real sockets.
//!
//! The mirror image of [`super::server`]: every trait method becomes one
//! HTTP request (ranged reads of length zero become a HEAD — HTTP cannot
//! spell an empty byte range — with the clamp check applied locally,
//! which is observationally identical). Connections are pooled and
//! reused (HTTP/1.1 keep-alive); a request that fails on a pooled —
//! possibly stale — connection is retried once on a fresh one.
//!
//! Name-bearing errors are reconstructed from the response's
//! `x-error-kind` plus the *caller's* names, so a `NoSuchKey` from a
//! remote gateway is byte-identical to one from an in-process backend —
//! including when a [`namespace`](HttpBackend::connect) prefixes
//! container names on the wire (the HTTP analogue of the `fs` backend's
//! unique per-run subdirectory).

//! Server-side backpressure — a real `429 Too Many Requests` from the
//! gateway's token-bucket limiter, or a `503 over-capacity` shed at the
//! connection cap — is absorbed *below* the `Backend` trait: both are
//! written before the request executes, so the client sleeps out the
//! server's `Retry-After` and blindly re-sends within a bounded budget.
//! Callers above the trait (the store front end, the stress workers)
//! see identical op counts and results whether the gateway throttles or
//! not; [`HttpBackend::throttled_429s`]/[`HttpBackend::shed_503s`]
//! count what was absorbed.

use super::encoding::{encode_query, meta_header, pct_decode, pct_encode};
use super::http::{read_response, write_request, Headers, Response, STALE_CONNECTION};
use crate::objectstore::backend::{
    clamp_range, AssembledUpload, Backend, BackendError, ListPage, ObjectStat,
};
use crate::objectstore::container::ObjectSummary;
use crate::objectstore::object::{Metadata, Object};
use crate::simclock::SimInstant;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A `Backend` over the gateway's REST protocol. `Send + Sync`; safe to
/// share across executor threads (each request takes a pooled
/// connection for its duration).
pub struct HttpBackend {
    addr: String,
    /// Optional container namespace: `c` travels as `{ns}.{c}`.
    ns: Option<String>,
    /// Bearer token sent as `Authorization` on every request.
    token: Option<String>,
    /// Idle keep-alive connections, at most [`MAX_POOLED_IDLE`].
    pool: Mutex<Vec<TcpStream>>,
    /// `429`s absorbed by the backpressure retry loop.
    throttled: AtomicU64,
    /// Over-capacity `503`s absorbed by the backpressure retry loop.
    shed: AtomicU64,
}

/// Most blind re-sends after backpressure rejections before the
/// rejection surfaces to the caller as an error.
const MAX_BACKPRESSURE_RETRIES: u32 = 32;
/// Total wall-clock sleep budget across one request's backpressure
/// retries.
const MAX_BACKPRESSURE_WAIT: Duration = Duration::from_secs(30);
/// Cap on a single `Retry-After` sleep, so a hostile header cannot
/// park a worker for minutes.
const MAX_RETRY_AFTER_SECS: f64 = 5.0;

/// The server's `Retry-After`, parsed as (possibly fractional)
/// delta-seconds per RFC 9110; a missing or unparseable header falls
/// back to a small flat pause.
fn retry_after(resp: &Response) -> Duration {
    let secs = resp
        .headers
        .get("retry-after")
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|s| s.is_finite() && *s >= 0.0)
        .unwrap_or(0.05);
    Duration::from_secs_f64(secs.min(MAX_RETRY_AFTER_SECS))
}

/// Cap on idle pooled connections per backend. Under a concurrency
/// burst every in-flight request holds its own connection (unbounded by
/// design — the server is thread-per-connection), but once the burst
/// drains only this many sockets are kept; the rest close on drop
/// instead of accumulating as idle fds for the life of the backend.
pub const MAX_POOLED_IDLE: usize = 8;

fn io_err(ctx: &str, e: std::io::Error) -> BackendError {
    BackendError::Io(format!("http backend {ctx}: {e}"))
}

/// A failed exchange, tagged with whether the failure proves the server
/// never executed the request (making a re-send safe):
///
/// * a **write-side** failure — the request never fully reached the
///   server, so it cannot have been parsed, let alone executed;
/// * EOF **before any response byte** ([`STALE_CONNECTION`]) — the
///   gateway answers every request it parses, so a connection that
///   closed without a single byte never processed one.
///
/// A failure while reading a partially received response gives no such
/// guarantee and is NOT retried: several requests are not idempotent.
struct SendFailure {
    retry_safe: bool,
    error: std::io::Error,
}

impl HttpBackend {
    /// Connect to a gateway at `addr` (`host:port`, with an optional
    /// `http://` prefix). `ns`, when given, prefixes every container
    /// name on the wire so independent clients of one served store get
    /// disjoint worlds. Fails fast if the gateway is unreachable.
    pub fn connect(addr: &str, ns: Option<String>) -> Result<Self, BackendError> {
        let addr = addr.trim_start_matches("http://").trim_end_matches('/');
        if !addr.contains(':') {
            return Err(BackendError::Io(format!(
                "http backend address '{addr}' must be HOST:PORT"
            )));
        }
        let probe = TcpStream::connect(addr).map_err(|e| io_err("connect", e))?;
        Ok(Self {
            addr: addr.to_string(),
            ns,
            token: None,
            pool: Mutex::new(vec![probe]),
            throttled: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    /// Attach a bearer token, sent as `Authorization: Bearer <token>` on
    /// every request (required when the gateway runs with `auth_token`).
    pub fn with_token(mut self, token: impl Into<String>) -> Self {
        self.token = Some(token.into());
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// `429`s absorbed (slept out and re-sent) by this backend.
    pub fn throttled_429s(&self) -> u64 {
        self.throttled.load(Ordering::Relaxed)
    }

    /// Over-capacity `503`s absorbed by this backend.
    pub fn shed_503s(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    fn wire_container(&self, container: &str) -> String {
        match &self.ns {
            Some(ns) => format!("{ns}.{container}"),
            None => container.to_string(),
        }
    }

    fn strip_ns(&self, wire: &str) -> String {
        match &self.ns {
            Some(ns) => wire
                .strip_prefix(&format!("{ns}."))
                .unwrap_or(wire)
                .to_string(),
            None => wire.to_string(),
        }
    }

    fn object_target(&self, container: &str, key: &str) -> String {
        format!(
            "/v1/{}/{}",
            pct_encode(&self.wire_container(container)),
            pct_encode(key)
        )
    }

    fn container_target(&self, container: &str) -> String {
        format!("/v1/{}", pct_encode(&self.wire_container(container)))
    }

    /// Issue one request, absorbing server-side backpressure: a `429`
    /// (token bucket drained) or an over-capacity `503` (shed at the
    /// connection cap) is written *before* the request executes, so the
    /// client sleeps out the server's `Retry-After` and re-sends —
    /// blindly, for every verb — within a bounded budget. Past the
    /// budget the rejection is returned and the caller maps it to an
    /// error. Any other response passes through untouched.
    fn request(
        &self,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, BackendError> {
        let mut attempts = 0u32;
        let mut waited = Duration::ZERO;
        loop {
            let resp = self.exchange(method, target, headers, body)?;
            let backpressure = resp.status == 429
                || (resp.status == 503
                    && resp.headers.get("x-error-kind") == Some("over-capacity"));
            if !backpressure {
                return Ok(resp);
            }
            let pause = retry_after(&resp);
            attempts += 1;
            if attempts > MAX_BACKPRESSURE_RETRIES || waited + pause > MAX_BACKPRESSURE_WAIT {
                return Ok(resp);
            }
            if resp.status == 429 {
                self.throttled.fetch_add(1, Ordering::Relaxed);
            } else {
                self.shed.fetch_add(1, Ordering::Relaxed);
            }
            std::thread::sleep(pause);
            waited += pause;
        }
    }

    /// One wire exchange, reusing a pooled connection when available. A
    /// pooled connection may have gone stale; the request is re-sent on
    /// a fresh connection ONLY when the failure proves the server never
    /// executed it (see [`SendFailure`]) — a blind re-send could leak an
    /// orphaned upload from `initiate` or turn a successful
    /// `create_container` into a spurious 409.
    fn exchange(
        &self,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, BackendError> {
        let authed;
        let headers = match &self.token {
            None => headers,
            Some(token) => {
                let mut h = headers.clone();
                h.push("Authorization", format!("Bearer {token}"));
                authed = h;
                &authed
            }
        };
        let pooled = self.pool.lock().unwrap().pop();
        if let Some(stream) = pooled {
            match self.send_on(stream, method, target, headers, body) {
                Ok(resp) => return Ok(resp),
                Err(f) if f.retry_safe => { /* stale; reconnect */ }
                Err(f) => return Err(io_err("request", f.error)),
            }
        }
        let fresh = TcpStream::connect(&self.addr).map_err(|e| io_err("connect", e))?;
        self.send_on(fresh, method, target, headers, body)
            .map_err(|f| io_err("request", f.error))
    }

    fn send_on(
        &self,
        stream: TcpStream,
        method: &str,
        target: &str,
        headers: &Headers,
        body: &[u8],
    ) -> Result<Response, SendFailure> {
        let mut write_half = stream.try_clone().map_err(|error| SendFailure {
            retry_safe: true,
            error,
        })?;
        // Write-side failures are retry-safe: the request never fully
        // left, so the server cannot have parsed (or executed) it.
        write_request(&mut write_half, method, target, headers, body).map_err(|error| {
            SendFailure {
                retry_safe: true,
                error,
            }
        })?;
        let mut reader = BufReader::new(stream);
        let resp = read_response(&mut reader).map_err(|error| SendFailure {
            // Read-side: only EOF before any response byte proves the
            // request was never processed.
            retry_safe: error.kind() == std::io::ErrorKind::UnexpectedEof
                && error.to_string() == STALE_CONNECTION,
            error,
        })?;
        // The whole body was consumed; the connection is reusable —
        // but only up to the idle cap: beyond it, dropping the stream
        // closes the socket and the pool stops growing.
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < MAX_POOLED_IDLE {
            pool.push(reader.into_inner());
        }
        Ok(resp)
    }

    /// Current idle pooled connections (test/diagnostic hook).
    pub fn pooled_idle(&self) -> usize {
        self.pool.lock().unwrap().len()
    }

    /// Rebuild the exact [`BackendError`] from a gateway error response,
    /// using the caller's (un-namespaced) names.
    fn decode_error(
        &self,
        resp: &Response,
        container: &str,
        key: &str,
        upload_id: u64,
    ) -> BackendError {
        let msg = || {
            resp.headers
                .get("x-error-msg")
                .and_then(pct_decode)
                .unwrap_or_else(|| format!("HTTP {}", resp.status))
        };
        match resp.headers.get("x-error-kind") {
            Some("no-such-container") => BackendError::NoSuchContainer(container.to_string()),
            Some("no-such-key") => BackendError::no_such_key(container, key),
            Some("container-exists") => {
                BackendError::ContainerAlreadyExists(container.to_string())
            }
            Some("no-such-upload") => BackendError::NoSuchUpload(upload_id),
            Some("invalid-request") => BackendError::InvalidRequest(msg()),
            Some("invalid-range") => {
                // Rebuilt below by the ranged-read path (it knows the
                // offset); elsewhere surface the raw message.
                BackendError::InvalidRange(msg())
            }
            Some("io") => BackendError::Io(msg()),
            Some("unauthorized") => {
                BackendError::Io("gateway auth: 401 unauthorized (missing bearer token)".into())
            }
            Some("forbidden") => {
                BackendError::Io("gateway auth: 403 forbidden (bearer token rejected)".into())
            }
            Some("throttled") => BackendError::Io(
                "gateway throttled: 429 persisted past the client retry budget".into(),
            ),
            Some("over-capacity") => BackendError::Io(
                "gateway over capacity: 503 persisted past the client retry budget".into(),
            ),
            _ => BackendError::Io(format!(
                "unexpected gateway response: HTTP {} for {container}/{key}",
                resp.status
            )),
        }
    }

    fn meta_headers(metadata: &Metadata) -> Headers {
        let mut headers = Headers::new();
        for (k, v) in metadata {
            let (name, value) = meta_header(k, v);
            headers.push(name, value);
        }
        headers
    }

    /// Decode the stat carried on an object response's headers.
    fn decode_stat(resp: &Response) -> Result<ObjectStat, BackendError> {
        let etag = resp
            .headers
            .get("etag")
            .map(|v| v.trim_matches('"'))
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(|| BackendError::Io("gateway response missing ETag".into()))?;
        let size: u64 = resp
            .headers
            .get("x-object-size")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| BackendError::Io("gateway response missing x-object-size".into()))?;
        let created_at = SimInstant(
            resp.headers
                .get("x-sim-created-at")
                .and_then(|v| v.parse().ok())
                .unwrap_or(0),
        );
        Ok(ObjectStat {
            size,
            etag,
            metadata: Self::decode_meta(resp)?,
            created_at,
        })
    }

    fn decode_meta(resp: &Response) -> Result<Metadata, BackendError> {
        let mut md = Metadata::new();
        for (k, v) in resp.headers.with_prefix("x-object-meta-") {
            let (Some(k), Some(v)) = (pct_decode(k), pct_decode(v)) else {
                return Err(BackendError::Io("undecodable x-object-meta header".into()));
            };
            md.insert(k, v);
        }
        Ok(md)
    }
}

impl Backend for HttpBackend {
    fn name(&self) -> &'static str {
        "http"
    }

    fn create_container(&self, name: &str) -> Result<(), BackendError> {
        let resp = self.request("PUT", &self.container_target(name), &Headers::new(), b"")?;
        match resp.status {
            201 => Ok(()),
            _ => Err(self.decode_error(&resp, name, "", 0)),
        }
    }

    fn container_exists(&self, name: &str) -> bool {
        // The trait returns a bare bool, so a transport failure cannot
        // surface as an error here; warn loudly instead of letting a
        // dead gateway masquerade as a missing container (the very next
        // fallible operation will surface the real I/O error).
        match self.request("HEAD", &self.container_target(name), &Headers::new(), b"") {
            Ok(resp) => resp.status == 200,
            Err(e) => {
                eprintln!("warning: http backend container_exists({name}): {e}");
                false
            }
        }
    }

    fn put(&self, container: &str, key: &str, obj: Object) -> Result<bool, BackendError> {
        let mut headers = Self::meta_headers(&obj.metadata);
        headers.push("x-sim-created-at", obj.created_at.0.to_string());
        let resp = self.request(
            "PUT",
            &self.object_target(container, key),
            &headers,
            &obj.data,
        )?;
        match resp.status {
            201 => Ok(resp.headers.get("x-replaced") == Some("true")),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn get(&self, container: &str, key: &str) -> Result<Object, BackendError> {
        let resp = self.request("GET", &self.object_target(container, key), &Headers::new(), b"")?;
        match resp.status {
            200 => {
                let stat = Self::decode_stat(&resp)?;
                Ok(Object {
                    data: Arc::new(resp.body),
                    metadata: stat.metadata,
                    created_at: stat.created_at,
                    etag: stat.etag,
                })
            }
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn get_range(
        &self,
        container: &str,
        key: &str,
        offset: u64,
        len: u64,
    ) -> Result<(Vec<u8>, ObjectStat), BackendError> {
        if len == 0 {
            // HTTP cannot spell an empty byte range; a HEAD plus the
            // shared clamp check is observationally identical (stat +
            // empty slice, or the 416 for offsets past EOF).
            let stat = self.head(container, key)?;
            clamp_range(container, key, offset, 0, stat.size)?;
            return Ok((Vec::new(), stat));
        }
        let mut headers = Headers::new();
        headers.push("Range", format!("bytes={}-{}", offset, offset + len - 1));
        let resp = self.request("GET", &self.object_target(container, key), &headers, b"")?;
        match resp.status {
            206 => {
                let stat = Self::decode_stat(&resp)?;
                Ok((resp.body, stat))
            }
            416 => {
                // Rebuild the clamp_range error exactly, from the
                // standard `Content-Range: bytes */SIZE` total.
                let size: u64 = resp
                    .headers
                    .get("content-range")
                    .and_then(|v| v.strip_prefix("bytes */"))
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(0);
                Err(clamp_range(container, key, offset, len, size)
                    .err()
                    .unwrap_or_else(|| {
                        BackendError::Io("gateway 416 for a satisfiable range".into())
                    }))
            }
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn head(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        let resp = self.request("HEAD", &self.object_target(container, key), &Headers::new(), b"")?;
        match resp.status {
            200 => Self::decode_stat(&resp),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn delete(&self, container: &str, key: &str) -> Result<ObjectStat, BackendError> {
        let resp = self.request(
            "DELETE",
            &self.object_target(container, key),
            &Headers::new(),
            b"",
        )?;
        match resp.status {
            204 => Self::decode_stat(&resp),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn list_page(
        &self,
        container: &str,
        prefix: &str,
        start_after: Option<&str>,
        max_keys: usize,
    ) -> Result<ListPage, BackendError> {
        let mut params = vec![
            ("prefix", prefix.to_string()),
            ("limit", max_keys.to_string()),
        ];
        if let Some(marker) = start_after {
            params.push(("marker", marker.to_string()));
        }
        let target = format!("{}?{}", self.container_target(container), encode_query(&params));
        let resp = self.request("GET", &target, &Headers::new(), b"")?;
        if resp.status != 200 {
            return Err(self.decode_error(&resp, container, "", 0));
        }
        let text = String::from_utf8(resp.body)
            .map_err(|_| BackendError::Io("non-UTF-8 listing body".into()))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let mut cols = line.split(' ');
            let (Some(name_enc), Some(size_s), Some(etag_s), None) =
                (cols.next(), cols.next(), cols.next(), cols.next())
            else {
                return Err(BackendError::Io(format!("malformed listing line '{line}'")));
            };
            let (Some(name), Ok(size), Ok(etag)) = (
                pct_decode(name_enc),
                size_s.parse::<u64>(),
                u64::from_str_radix(etag_s, 16),
            ) else {
                return Err(BackendError::Io(format!("malformed listing line '{line}'")));
            };
            entries.push(ObjectSummary { name, size, etag });
        }
        let next = match resp.headers.get("x-next-marker") {
            None => None,
            Some(enc) => Some(
                pct_decode(enc)
                    .ok_or_else(|| BackendError::Io("undecodable x-next-marker".into()))?,
            ),
        };
        Ok(ListPage { entries, next })
    }

    fn initiate_multipart(
        &self,
        container: &str,
        key: &str,
        metadata: Metadata,
    ) -> Result<u64, BackendError> {
        let headers = Self::meta_headers(&metadata);
        let target = format!("{}?uploads=", self.object_target(container, key));
        let resp = self.request("POST", &target, &headers, b"")?;
        match resp.status {
            200 => resp
                .headers
                .get("x-upload-id")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| BackendError::Io("gateway response missing x-upload-id".into())),
            _ => Err(self.decode_error(&resp, container, key, 0)),
        }
    }

    fn upload_part(
        &self,
        upload_id: u64,
        part_number: u32,
        data: Vec<u8>,
    ) -> Result<(), BackendError> {
        let target = format!("/v1-upload/{upload_id}/{part_number}");
        let resp = self.request("PUT", &target, &Headers::new(), &data)?;
        match resp.status {
            201 => Ok(()),
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn complete_multipart(
        &self,
        upload_id: u64,
        min_part_size: u64,
    ) -> Result<AssembledUpload, BackendError> {
        let target = format!("/v1-upload/{upload_id}?min-part-size={min_part_size}");
        let resp = self.request("POST", &target, &Headers::new(), b"")?;
        match resp.status {
            200 => {
                let container_wire = resp
                    .headers
                    .get("x-container")
                    .and_then(pct_decode)
                    .ok_or_else(|| BackendError::Io("gateway response missing x-container".into()))?;
                let key = resp
                    .headers
                    .get("x-key")
                    .and_then(pct_decode)
                    .ok_or_else(|| BackendError::Io("gateway response missing x-key".into()))?;
                Ok(AssembledUpload {
                    container: self.strip_ns(&container_wire),
                    key,
                    data: resp.body,
                    metadata: Self::decode_meta(&resp)?,
                })
            }
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn abort_multipart(&self, upload_id: u64) -> Result<(), BackendError> {
        let target = format!("/v1-upload/{upload_id}");
        let resp = self.request("DELETE", &target, &Headers::new(), b"")?;
        match resp.status {
            204 => Ok(()),
            _ => Err(self.decode_error(&resp, "", "", upload_id)),
        }
    }

    fn multipart_in_flight(&self) -> usize {
        self.request("GET", "/v1-upload", &Headers::new(), b"")
            .ok()
            .filter(|resp| resp.status == 200)
            .and_then(|resp| String::from_utf8(resp.body).ok())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }

    fn live_count(&self, container: &str) -> usize {
        self.live_stat(container, "count") as usize
    }

    fn live_bytes(&self, container: &str) -> u64 {
        self.live_stat(container, "bytes")
    }
}

impl HttpBackend {
    fn live_stat(&self, container: &str, which: &str) -> u64 {
        let target = format!("{}?live={which}", self.container_target(container));
        self.request("GET", &target, &Headers::new(), b"")
            .ok()
            .filter(|resp| resp.status == 200)
            .and_then(|resp| String::from_utf8(resp.body).ok())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::GatewayServer;
    use crate::objectstore::backend::ShardedMemBackend;

    #[test]
    fn retry_after_parses_fractional_integer_and_garbage() {
        let with = |v: &str| Response::new(429).with_header("Retry-After", v);
        assert_eq!(retry_after(&with("0.02")), Duration::from_secs_f64(0.02));
        assert_eq!(retry_after(&with("1")), Duration::from_secs(1));
        // Hostile values fall back or clamp instead of parking a worker.
        assert_eq!(retry_after(&with("soon")), Duration::from_secs_f64(0.05));
        assert_eq!(retry_after(&with("-3")), Duration::from_secs_f64(0.05));
        assert_eq!(
            retry_after(&with("99999")),
            Duration::from_secs_f64(MAX_RETRY_AFTER_SECS)
        );
        assert_eq!(retry_after(&Response::new(429)), Duration::from_secs_f64(0.05));
    }

    #[test]
    fn idle_pool_is_capped_and_recovers_after_a_burst() {
        let server = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
            .expect("bind ephemeral");
        let handle = server.spawn();
        let b = Arc::new(HttpBackend::connect(&handle.addr().to_string(), None).unwrap());
        b.create_container("res").unwrap();
        // Exhaust: a burst far wider than the cap, every thread holding
        // a connection at once (a barrier forces the overlap, so the
        // pool is empty mid-burst and each thread opens its own socket).
        let n = 4 * MAX_POOLED_IDLE;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let threads: Vec<_> = (0..n)
            .map(|i| {
                let b = b.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let key = format!("k/{i}");
                    let obj = Object::new(vec![i as u8; 64], Metadata::new(), SimInstant::EPOCH);
                    b.put("res", &key, obj).unwrap();
                    assert_eq!(b.get("res", &key).unwrap().size(), 64);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // Release: the burst drained; the pool kept at most the cap.
        assert!(
            b.pooled_idle() <= MAX_POOLED_IDLE,
            "pool grew to {} (> cap {MAX_POOLED_IDLE})",
            b.pooled_idle()
        );
        // Recover: the backend still serves requests afterwards.
        assert_eq!(b.live_count("res"), n);
        b.put("res", "after", Object::new(b"x".to_vec(), Metadata::new(), SimInstant::EPOCH))
            .unwrap();
        assert_eq!(&**b.get("res", "after").unwrap().data, b"x");
        assert!(b.pooled_idle() <= MAX_POOLED_IDLE);
    }
}
