//! Per-connection state machine for the reactor core.
//!
//! A [`Conn`] owns one non-blocking `TcpStream` and two buffers. Each
//! [`Conn::poll`] pass advances the machine as far as the socket
//! allows without ever blocking: flush pending output, read available
//! input, parse-and-serve every complete request the input buffer
//! holds (pipelining included), then apply the stall/drain policies.
//! See the module docs on [`super`] for the design rules.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::gateway::config::{ChaosAction, Gatekeeper, STALL_HOLD};
use crate::gateway::http::{try_parse_request, write_response, Response};
use crate::gateway::server::{chaos_cut, chaos_disposition, elapsed_nanos, process_request_traced};
use crate::objectstore::backend::Backend;

/// Read at most this much per poll pass, so one firehose peer cannot
/// starve every other connection in the sweep.
const READ_QUOTA: usize = 64 * 1024;

/// Bytes a sweep pass moved across every connection it polled. The
/// loop owns one per pass and feeds it to the observability plane's
/// sweep stats — plain integers on the stack, so per-connection
/// accounting costs nothing beyond the additions themselves.
#[derive(Default)]
pub(super) struct IoTally {
    pub(super) bytes_in: u64,
    pub(super) bytes_out: u64,
}

pub(super) struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet consumed by the parser. Non-empty
    /// means a partial request is pending (the slow-loris clock runs);
    /// empty means the connection is an idle keep-alive (never reaped).
    inbuf: Vec<u8>,
    /// Serialized response bytes not yet accepted by the socket.
    outbuf: Vec<u8>,
    written: usize,
    last_progress: Instant,
    /// Close once `outbuf` drains (set on malformed input, 408, drain).
    close_after_flush: bool,
    /// `stall` chaos: the response is withheld until this instant, then
    /// the connection closes without writing it. While set, the sweep
    /// skips this connection entirely (never blocking anyone else).
    stall_until: Option<Instant>,
    /// Peer half-closed its write side; serve what's buffered, then close.
    peer_eof: bool,
    closed: bool,
}

impl Conn {
    pub(super) fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            written: 0,
            last_progress: Instant::now(),
            close_after_flush: false,
            stall_until: None,
            peer_eof: false,
            closed: false,
        }
    }

    pub(super) fn is_closed(&self) -> bool {
        self.closed
    }

    /// One readiness pass. Returns true if any byte moved or any
    /// request was served — the reactor only sleeps when a full sweep
    /// makes no progress anywhere. `now` is the sweep's pass-start
    /// instant (shared across every connection in the pass); bytes
    /// moved accumulate into `io`.
    pub(super) fn poll(
        &mut self,
        backend: &dyn Backend,
        gate: &Gatekeeper,
        now: Instant,
        draining: bool,
        io: &mut IoTally,
    ) -> bool {
        if self.closed {
            return false;
        }
        if let Some(deadline) = self.stall_until {
            // Stalled by chaos: hold everything unwritten until the
            // client's read deadline has surely passed, then close
            // without sending a byte.
            if now < deadline {
                return false;
            }
            self.outbuf.clear();
            self.written = 0;
            self.closed = true;
            return true;
        }
        let wrote = self.flush();
        io.bytes_out += wrote as u64;
        let mut progress = wrote > 0;
        if !self.closed && self.outbuf.is_empty() && !self.peer_eof {
            let read = self.fill();
            io.bytes_in += read as u64;
            progress |= read > 0;
        }
        progress |= self.serve_buffered(backend, gate, draining, now, io);
        if !self.closed
            && !self.inbuf.is_empty()
            && self.outbuf.is_empty()
            && now.duration_since(self.last_progress) > gate.cfg.read_timeout
        {
            // Slow loris: a partial request stalled past the read
            // timeout. Answer 408 and close. An idle keep-alive
            // (empty inbuf) never reaches this arm.
            self.enqueue(
                &Response::new(408).with_header("x-error-kind", "stalled-request"),
            );
            self.close_after_flush = true;
            let wrote = self.flush();
            io.bytes_out += wrote as u64;
            progress |= wrote > 0;
        }
        if draining && !self.closed && self.inbuf.is_empty() && self.outbuf.is_empty() {
            // Graceful drain: in-flight work above finished (or there
            // was none); idle keep-alives are closed immediately.
            self.closed = true;
        }
        progress
    }

    /// Parse-and-serve every complete request currently buffered.
    /// Responses are served strictly in order; serving pauses whenever
    /// the socket will not accept the previous response yet.
    ///
    /// This is where the reactor core measures the two phases the
    /// shared serve path cannot see: `parse` (around
    /// [`try_parse_request`], taken only when input is actually
    /// buffered — an idle keep-alive costs no clock read) and `queue`
    /// (serve start minus `pass_start`, the dispatch delay a request
    /// waited for its turn in the sweep).
    fn serve_buffered(
        &mut self,
        backend: &dyn Backend,
        gate: &Gatekeeper,
        draining: bool,
        pass_start: Instant,
        io: &mut IoTally,
    ) -> bool {
        let mut progress = false;
        let obs = gate.obs.enabled();
        while !self.closed && self.outbuf.is_empty() && self.stall_until.is_none() {
            let t_parse = (obs && !self.inbuf.is_empty()).then(Instant::now);
            match try_parse_request(&self.inbuf) {
                Ok(Some((mut req, consumed))) => {
                    self.inbuf.drain(..consumed);
                    let parse_nanos = t_parse.map_or(0, elapsed_nanos);
                    let queue_nanos = if obs {
                        Instant::now()
                            .saturating_duration_since(pass_start)
                            .as_nanos()
                            .min(u64::MAX as u128) as u64
                    } else {
                        0
                    };
                    let outcome =
                        process_request_traced(backend, gate, &mut req, queue_nanos, parse_nanos);
                    let bytes = outcome.bytes;
                    let action = gate.chaos_on_response();
                    if !matches!(action, ChaosAction::None) {
                        // The wire decision lands after the trace entry
                        // was pushed; patch the disposition in place.
                        if let Some(token) = outcome.trace {
                            gate.obs.trace.patch_disposition(token, chaos_disposition(action));
                        }
                    }
                    match action {
                        ChaosAction::None => self.outbuf.extend_from_slice(&bytes),
                        ChaosAction::Stall => {
                            // Park the connection; poll() closes it once
                            // the hold expires. The response bytes are
                            // dropped — the peer never sees them.
                            self.stall_until = Some(Instant::now() + STALL_HOLD);
                        }
                        action => {
                            // Kill/truncate: enqueue a strict prefix,
                            // then FIN after it drains — the peer reads
                            // a genuinely torn response.
                            let cut = chaos_cut(action, bytes.len());
                            self.outbuf.extend_from_slice(&bytes[..cut]);
                            self.close_after_flush = true;
                        }
                    }
                    if draining {
                        self.close_after_flush = true;
                    }
                    progress = true;
                    let wrote = self.flush();
                    io.bytes_out += wrote as u64;
                    progress |= wrote > 0;
                }
                Ok(None) => {
                    if self.peer_eof {
                        if self.inbuf.is_empty() {
                            // Clean close between requests.
                            self.closed = true;
                        } else {
                            // EOF inside a request: same 400-and-close
                            // as the blocking parser's "EOF inside
                            // headers" / "truncated body".
                            self.inbuf.clear();
                            self.enqueue(&Response::new(400));
                            self.close_after_flush = true;
                            let wrote = self.flush();
                            io.bytes_out += wrote as u64;
                            progress |= wrote > 0;
                        }
                    }
                    break;
                }
                Err(_) => {
                    // Malformed request: 400 and drop the connection —
                    // framing may be lost, same as the threaded core.
                    self.inbuf.clear();
                    self.enqueue(&Response::new(400));
                    self.close_after_flush = true;
                    let wrote = self.flush();
                    io.bytes_out += wrote as u64;
                    progress |= wrote > 0;
                    break;
                }
            }
        }
        progress
    }

    /// Read whatever the socket has, up to the per-pass quota. Returns
    /// the bytes moved into the input buffer.
    fn fill(&mut self) -> usize {
        let mut scratch = [0u8; 16 * 1024];
        let mut moved = 0usize;
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    self.peer_eof = true;
                    break;
                }
                Ok(n) => {
                    self.inbuf.extend_from_slice(&scratch[..n]);
                    moved += n;
                    self.last_progress = Instant::now();
                    if moved >= READ_QUOTA {
                        break;
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        moved
    }

    /// Push pending output into the socket; resumable across passes.
    /// Returns the bytes accepted by the socket this call.
    fn flush(&mut self) -> usize {
        if self.outbuf.is_empty() {
            if self.close_after_flush {
                self.closed = true;
            }
            return 0;
        }
        let mut wrote = 0usize;
        loop {
            match self.stream.write(&self.outbuf[self.written..]) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(n) => {
                    self.written += n;
                    self.last_progress = Instant::now();
                    wrote += n;
                    if self.written == self.outbuf.len() {
                        self.outbuf.clear();
                        self.written = 0;
                        if self.close_after_flush {
                            let _ = self.stream.shutdown(std::net::Shutdown::Write);
                            self.closed = true;
                        }
                        break;
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        wrote
    }

    fn enqueue(&mut self, resp: &Response) {
        // Serializing into a Vec cannot fail.
        let _ = write_response(&mut self.outbuf, resp);
    }
}
