//! The reactor gateway core: a std-only non-blocking event loop.
//!
//! Thread-per-connection tops out at a few thousand clients (one OS
//! stack each, scheduler pressure at every wakeup); the production
//! north star needs tens of thousands of mostly-idle keep-alive
//! connections. This core serves them all from **one** thread: the
//! listener and every accepted stream are switched to non-blocking
//! mode, and a sweep loop advances each connection's state machine
//! ([`conn::Conn`]) as far as its socket allows, without ever
//! blocking. No epoll/kqueue binding (std exposes none) — the loop
//! busy-sweeps while any socket is moving bytes and sleeps briefly
//! (`POLL_IDLE`) only after a full sweep makes no progress. With
//! in-memory backends whose operations complete in microseconds,
//! routing inline on the sweep thread is faster than any handoff.
//!
//! # State-machine design rules
//!
//! 1. **Never block.** Every socket op is non-blocking; `WouldBlock`
//!    parks the state where it stands, to be resumed on a later sweep.
//!    Partial writes resume from a byte offset; partial requests wait
//!    in the input buffer until [`try_parse_request`] sees a complete
//!    frame (`Ok(None)` = incomplete, *distinct from malformed*).
//! 2. **One response in flight per connection.** Requests are served
//!    strictly in arrival order; the parser is re-run only once the
//!    previous response has fully drained. Pipelined requests are
//!    served back-to-back within one sweep when the socket keeps up.
//! 3. **Reject before execute.** The shared [`Gatekeeper`] screens
//!    every parsed request (auth `401`/`403`, token-bucket `429` with
//!    `Retry-After`) before routing, and accepts beyond `max_conns`
//!    are shed with `503 over-capacity` before any request byte is
//!    read — so every rejection is provably unexecuted and clients may
//!    blindly re-send.
//! 4. **Stalls die, idleness lives.** A *partial* request that makes
//!    no progress for `read_timeout` gets `408` and a close (slow
//!    loris); an idle keep-alive connection (empty input buffer) is
//!    never reaped, however long it sits.
//! 5. **Drain, then die.** On shutdown the loop stops accepting,
//!    closes idle connections immediately, finishes requests already
//!    in flight, and gives up after `drain_timeout`.
//!
//! Routing, error mapping, wire formats, and the request-id replay
//! cache are shared with the threaded core
//! (`server::process_request`), so the two cores are byte-identical to
//! every client — pinned by running the full conformance suite against
//! both. The sweep loop feeds the observability plane once per pass
//! (connections polled, accept-burst depth, bytes moved, idle-sleep
//! ratio — [`crate::metrics::SweepStats`]); per-connection accounting
//! is two stack integers, and with the plane disabled the loop takes
//! no extra clock reads at all. The wire chaos plane is applied here at the same layer as the
//! threaded core: `reset` drops connections at accept, kill/truncate
//! enqueue a strict prefix of the serialized response, and `stall`
//! parks the connection unwritten past the client's read deadline —
//! all without ever blocking the sweep thread.

mod conn;

use std::io;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::config::Gatekeeper;
use super::server::shed_connection;
use crate::objectstore::backend::Backend;

use conn::{Conn, IoTally};

#[allow(unused_imports)] // referenced by the module docs
use super::http::try_parse_request;

/// Sleep after a sweep that moved no bytes anywhere. Long enough to
/// stay off the CPU when fully idle, short enough (< a loopback RTT
/// budget) to not show up in the stress latency histograms.
const POLL_IDLE: Duration = Duration::from_micros(500);

/// Most accepts taken per sweep, so an accept storm cannot starve
/// established connections.
const ACCEPT_BURST: usize = 256;

/// The reactor event loop. Runs until `stop` is set, then drains.
pub(crate) fn run_loop(
    listener: TcpListener,
    backend: Arc<dyn Backend>,
    gate: Arc<Gatekeeper>,
    stop: &AtomicBool,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let mut conns: Vec<Conn> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        let stopping = stop.load(Ordering::Relaxed);
        let mut progress = false;
        let mut accepted_this_pass = 0u64;
        if stopping {
            drain_deadline.get_or_insert_with(|| Instant::now() + gate.cfg.drain_timeout);
        } else {
            for _ in 0..ACCEPT_BURST {
                match listener.accept() {
                    Ok((stream, _)) => {
                        progress = true;
                        accepted_this_pass += 1;
                        if gate.chaos_at_accept() {
                            // `reset` chaos: drop the connection before
                            // reading a byte — provably unexecuted.
                            continue;
                        }
                        if conns.len() >= gate.cfg.max_conns {
                            let gate = gate.clone();
                            // Throwaway thread: the shed path does
                            // short blocking I/O we must not absorb.
                            std::thread::spawn(move || shed_connection(stream, &gate));
                        } else if stream.set_nonblocking(true).is_ok() {
                            conns.push(Conn::new(stream));
                        }
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
        }
        let now = Instant::now();
        let polled = conns.len() as u64;
        let mut io = IoTally::default();
        for conn in conns.iter_mut() {
            progress |= conn.poll(&*backend, &gate, now, stopping, &mut io);
        }
        conns.retain(|c| !c.is_closed());
        if gate.obs.enabled() {
            // One recording per sweep pass — cost is independent of how
            // many connections the pass visited.
            gate.obs
                .sweep
                .record_pass(polled, accepted_this_pass, io.bytes_in, io.bytes_out, !progress);
        }
        if stopping {
            let deadline = drain_deadline.expect("set on first stopping sweep");
            if conns.is_empty() || Instant::now() >= deadline {
                return;
            }
        }
        if !progress {
            std::thread::sleep(POLL_IDLE);
        }
    }
}
