//! HTTP object-store gateway: the simulator's REST semantics on real
//! sockets.
//!
//! Everything the paper argues about is a *wire protocol* — atomic PUT,
//! ranged GET with `Content-Range`/416, multipart initiate/part/
//! complete/abort, paginated prefix listings — yet the in-process
//! simulator moves every byte through function calls. This module closes
//! that gap with two mirror-image pieces, both dependency-free (std
//! `TcpListener`/`TcpStream` and hand-rolled HTTP/1.1, matching the
//! vendored-stubs constraint):
//!
//! * [`server::GatewayServer`] — a REST server exposing any
//!   [`crate::objectstore::Backend`] over Swift/S3-style routes
//!   (`PUT/GET/HEAD/DELETE /v1/{container}/{key}`, `Range` requests,
//!   `ETag` + `x-object-meta-*` headers, `?prefix=&marker=&limit=`
//!   listing pages, and the `/v1-upload` multipart lifecycle). Started
//!   from the CLI with `stocator-sim serve`.
//! * [`client::HttpBackend`] — a `Backend` *implementation* that speaks
//!   that protocol over pooled keep-alive `TcpStream`s, selected with
//!   `--backend http:HOST:PORT` on `run`/`sweep`.
//!
//! Because REST-op accounting, the latency model, the visibility
//! overlay and the fault plane all live in the
//! [`crate::objectstore::ObjectStore`] front end *above* the `Backend`
//! trait, a workload driven through `HttpBackend` produces op counts,
//! traces and virtual runtimes byte-identical to the in-memory
//! backends — the conformance suite and the golden-opcount tests pin
//! this by running against an in-process gateway on an ephemeral port.
//!
//! Keys are percent-encoded into the URL path ([`encoding`]), so
//! hostile names — spaces, `%`, unicode, `/`-bearing keys — round-trip
//! exactly; metadata rides as `x-object-meta-<pct-key>: <pct-value>`
//! headers and the virtual-clock creation instant as
//! `x-sim-created-at`.

//! Two interchangeable server cores sit behind the same routes: the
//! legacy thread-per-connection core and the [`reactor`] non-blocking
//! event loop (the `serve` default), selected — along with connection
//! caps, token-bucket `429` rate limiting, and bearer auth — by a
//! [`config::GatewayConfig`] resolved from TOML file, `STOCATOR_GATEWAY_*`
//! environment variables, and CLI flags.
//!
//! The wire is also where the robustness story lives: every mutating
//! request carries an `x-request-id`, the gatekeeper's bounded
//! [`config::ReplayCache`] answers duplicate ids with the original
//! response, and the client blindly re-sends on *any* send failure
//! within a bounded, jittered budget — so killed, truncated, stalled,
//! or reset connections (injectable deterministically via
//! [`config::ChaosConfig`], `--chaos`) never produce a wrong answer,
//! only a retried one.

pub mod client;
pub mod config;
pub mod encoding;
pub mod http;
pub mod reactor;
pub mod server;

pub use client::HttpBackend;
pub use config::{ChaosConfig, Gatekeeper, GatewayConfig, GatewayMode, ReplayCache};
pub use server::{GatewayHandle, GatewayServer};

/// A process-unique namespace tag. The harness gives every workload
/// environment one (see `harness::scenarios::build_env`), so repeated
/// runs and sweep cells against one long-lived served store never
/// collide on container creation — the HTTP analogue of the unique
/// per-env subdirectory the `fs` backend uses.
pub fn unique_namespace() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    format!(
        "w{}-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed),
        nanos
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn namespaces_are_unique() {
        assert_ne!(unique_namespace(), unique_namespace());
    }
}
