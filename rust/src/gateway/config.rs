//! Gateway configuration and the production control plane.
//!
//! [`GatewayConfig`] is resolved in strictly increasing precedence:
//! built-in defaults → TOML config file (`--config PATH`) →
//! `STOCATOR_GATEWAY_*` environment variables → explicit CLI flags.
//! The TOML reader is a deliberate std-only subset (one `key = value`
//! per line, `#` comments, an optional `[gateway]` section header) —
//! enough for a service config file without pulling in a parser crate.
//!
//! [`Gatekeeper`] is the part of the production plane that is shared
//! verbatim by both server cores (threaded and reactor): bearer-token
//! auth (`401` missing / `403` mismatch) and a token-bucket rate
//! limiter that emits *real* `429 Too Many Requests` with a
//! fractional-seconds `Retry-After` the client honors. `/healthz` is
//! exempt from both so readiness probes and idle keep-alive holders
//! never consume quota. Screening happens after a request is fully
//! parsed but before it is routed, so a `429`/`401`/`403` provably
//! never executed — which is what makes the client's blind re-send
//! safe for every verb, mutating ones included.
//!
//! Two more pieces of the robustness plane live here because both
//! cores share them through the gatekeeper: the [`ReplayCache`] that
//! makes *every* mutating request safely retryable (not just the
//! provably-unexecuted rejections above), and the [`ChaosConfig`] wire
//! fault injector that proves it.

use crate::metrics::{LiveCounters, ObsPlane};
use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::rng::Pcg32;

use super::http::{Request, Response};

/// Which connection-handling core the gateway runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatewayMode {
    /// Legacy thread-per-connection core (PR 5). Library default, so
    /// `GatewayServer::bind` keeps its original behavior byte-for-byte.
    Threaded,
    /// Single-threaded non-blocking event loop (`gateway::reactor`).
    /// Default for the `serve` CLI.
    Reactor,
}

impl GatewayMode {
    pub fn parse(s: &str) -> Result<GatewayMode, String> {
        match s.trim() {
            "threaded" => Ok(GatewayMode::Threaded),
            "reactor" => Ok(GatewayMode::Reactor),
            other => Err(format!(
                "unknown gateway mode '{other}' (expected 'reactor' or 'threaded')"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GatewayMode::Threaded => "threaded",
            GatewayMode::Reactor => "reactor",
        }
    }
}

/// Wire-level chaos plane: per-response / per-accept fault
/// probabilities, injected at the *connection* layer of both server
/// cores — below HTTP routing, after the request executed. This is
/// deliberately nastier than the PR 4 `--faults` store plane (which
/// fires inside the store front end, above the wire): a killed
/// response leaves the client unable to tell whether its PUT ran.
/// Spec grammar (CLI/TOML/env value for the `chaos` key):
///
/// ```text
/// kill-response@p=0.02,truncate@p=0.01,stall@p=0.001,reset@p=0.01
/// ```
///
/// * `kill-response` — write a short prefix of the serialized
///   response, then close the socket (cut inside the status/headers).
/// * `truncate` — write all but the tail of the response, then close
///   (cut inside the body: a `Content-Length` that never arrives).
/// * `stall` — hold the response unwritten past the client's read
///   deadline, then close without sending a byte.
/// * `reset` — drop the connection at accept, before reading anything.
///
/// Draws come from one seeded PCG32 stream (`chaos_seed`), so a chaos
/// run is reproducible. All probabilities default to `0.0` = off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    pub kill_response: f64,
    pub truncate: f64,
    pub stall: f64,
    pub reset: f64,
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig { kill_response: 0.0, truncate: 0.0, stall: 0.0, reset: 0.0, seed: 7 }
    }
}

impl ChaosConfig {
    /// Parse the comma-separated `name@p=PROB` grammar. An empty spec
    /// or `off` disables every fault. The seed is a separate key
    /// (`chaos_seed` / `--chaos-seed`) and is left at its default here.
    pub fn parse(spec: &str) -> Result<ChaosConfig, String> {
        let mut cfg = ChaosConfig::default();
        let spec = spec.trim();
        if spec.is_empty() || spec == "off" {
            return Ok(cfg);
        }
        for clause in spec.split(',') {
            let clause = clause.trim();
            let (name, prob) = clause.split_once("@p=").ok_or_else(|| {
                format!("bad chaos clause '{clause}' (expected NAME@p=PROB)")
            })?;
            let p: f64 = prob
                .trim()
                .parse()
                .map_err(|_| format!("bad chaos probability '{prob}' in '{clause}'"))?;
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("chaos probability must be in [0, 1], got '{prob}'"));
            }
            match name.trim() {
                "kill-response" => cfg.kill_response = p,
                "truncate" => cfg.truncate = p,
                "stall" => cfg.stall = p,
                "reset" => cfg.reset = p,
                other => {
                    return Err(format!(
                        "unknown chaos fault '{other}' \
                         (expected kill-response, truncate, stall, or reset)"
                    ))
                }
            }
        }
        Ok(cfg)
    }

    /// Any fault armed? A fully-zero config is exactly "chaos off" —
    /// the invariance tests pin that the two are indistinguishable.
    pub fn is_active(&self) -> bool {
        self.kill_response > 0.0 || self.truncate > 0.0 || self.stall > 0.0 || self.reset > 0.0
    }

    /// Canonical spec string (round-trips through [`ChaosConfig::parse`]).
    pub fn spec(&self) -> String {
        if !self.is_active() {
            return "off".to_string();
        }
        let mut parts = Vec::new();
        for (name, p) in [
            ("kill-response", self.kill_response),
            ("truncate", self.truncate),
            ("stall", self.stall),
            ("reset", self.reset),
        ] {
            if p > 0.0 {
                parts.push(format!("{name}@p={p}"));
            }
        }
        parts.join(",")
    }
}

/// What the chaos plane does to one response about to be written.
/// `Reset` never appears here — it is drawn separately at accept time
/// via [`Gatekeeper::chaos_at_accept`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    None,
    KillResponse,
    Truncate,
    Stall,
}

/// How long a `stall`ed response is held unwritten before the server
/// closes the connection. Must exceed the client's read deadline
/// (`gateway::client::CLIENT_READ_TIMEOUT`, 2s) so the client times
/// out first and exercises its blind-re-send path.
pub(crate) const STALL_HOLD: Duration = Duration::from_secs(3);

/// Stable display names for the per-kind chaos injection counters, in
/// the same order as [`ChaosPlan::by_kind`]'s array.
pub const CHAOS_KINDS: [&str; 4] = ["kill-response", "truncate", "stall", "reset"];

/// Runtime state of the chaos plane: the seeded draw stream plus
/// injection counters (observability for tests, the CLI, and
/// `/metricz`, which breaks injections out per fault kind).
pub(crate) struct ChaosPlan {
    cfg: ChaosConfig,
    rng: Mutex<Pcg32>,
    injected: AtomicU64,
    /// Per-kind injection counts, [`CHAOS_KINDS`] order.
    by_kind: [AtomicU64; 4],
}

impl ChaosPlan {
    fn new(cfg: ChaosConfig) -> ChaosPlan {
        ChaosPlan {
            cfg,
            rng: Mutex::new(Pcg32::with_stream(cfg.seed, 0xc4a0_5eed)),
            injected: AtomicU64::new(0),
            by_kind: Default::default(),
        }
    }

    fn draw(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        rng.chance(p)
    }

    fn inject(&self, kind_idx: usize) {
        self.injected.fetch_add(1, Ordering::Relaxed);
        self.by_kind[kind_idx].fetch_add(1, Ordering::Relaxed);
    }

    fn at_accept(&self) -> bool {
        let hit = self.draw(self.cfg.reset);
        if hit {
            self.inject(3);
        }
        hit
    }

    fn on_response(&self) -> ChaosAction {
        let (action, kind_idx) = if self.draw(self.cfg.kill_response) {
            (ChaosAction::KillResponse, 0)
        } else if self.draw(self.cfg.truncate) {
            (ChaosAction::Truncate, 1)
        } else if self.draw(self.cfg.stall) {
            (ChaosAction::Stall, 2)
        } else {
            return ChaosAction::None;
        };
        self.inject(kind_idx);
        action
    }
}

/// Resolved gateway configuration. See the module docs for precedence.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayConfig {
    pub mode: GatewayMode,
    /// Hard cap on simultaneous connections; excess accepts are shed
    /// with an immediate `503` + `x-error-kind: over-capacity`.
    pub max_conns: usize,
    /// Sustained request rate in requests/second; `0.0` disables the
    /// limiter entirely (the default — conformance stays byte-identical).
    pub rate_limit: f64,
    /// Token-bucket capacity: how many requests may burst above the
    /// sustained rate before `429`s start.
    pub burst: u32,
    /// When set, every non-`/healthz` request must carry
    /// `Authorization: Bearer <token>`.
    pub auth_token: Option<String>,
    /// Slow-loris guard: a connection holding a *partial* request this
    /// long with no progress gets `408` and is closed. Idle keep-alive
    /// connections (empty input buffer) are never reaped.
    pub read_timeout: Duration,
    /// Graceful-shutdown budget: in-flight requests get this long to
    /// finish before the reactor gives up and returns.
    pub drain_timeout: Duration,
    /// Wire-level fault injection (see [`ChaosConfig`]); all-zero
    /// probabilities (the default) mean the chaos plane is off.
    pub chaos: ChaosConfig,
    /// Observability plane master switch: latency/byte histograms,
    /// reactor sweep stats, and the `/tracez` ring. On by default —
    /// recording is wait-free and never touches a lock on the request
    /// path — but can be switched off to run the A/B invariance proof
    /// (`observability_never_changes_op_counts_or_virtual_runtimes`).
    pub observability: bool,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            mode: GatewayMode::Threaded,
            max_conns: 16_384,
            rate_limit: 0.0,
            burst: 64,
            auth_token: None,
            read_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(2),
            chaos: ChaosConfig::default(),
            observability: true,
        }
    }
}

impl GatewayConfig {
    /// Defaults for the `serve` CLI: same as [`Default`] but the
    /// reactor core, so new deployments get the scalable path while
    /// the library entry point stays backward compatible.
    pub fn serve_default() -> Self {
        GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() }
    }

    /// Set one configuration key from its string form. Shared by the
    /// TOML reader, the env-var layer, and the CLI so all three agree
    /// on names, parsing, and validation.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String> {
            value
                .trim()
                .parse::<T>()
                .map_err(|_| format!("bad value '{value}' for gateway key '{key}'"))
        }
        match key {
            "mode" => self.mode = GatewayMode::parse(value)?,
            "max_conns" => {
                self.max_conns = num::<usize>(key, value)?;
                if self.max_conns == 0 {
                    return Err("max_conns must be >= 1".into());
                }
            }
            "rate_limit" => {
                self.rate_limit = num::<f64>(key, value)?;
                if !self.rate_limit.is_finite() || self.rate_limit < 0.0 {
                    return Err(format!("rate_limit must be finite and >= 0, got '{value}'"));
                }
            }
            "burst" => {
                self.burst = num::<u32>(key, value)?;
                if self.burst == 0 {
                    return Err("burst must be >= 1".into());
                }
            }
            "auth_token" => {
                let t = value.trim();
                self.auth_token = if t.is_empty() { None } else { Some(t.to_string()) };
            }
            "read_timeout_ms" => self.read_timeout = Duration::from_millis(num(key, value)?),
            "drain_timeout_ms" => self.drain_timeout = Duration::from_millis(num(key, value)?),
            "chaos" => {
                // Re-parsing must not clobber a seed set by an earlier
                // (lower-precedence) layer.
                let seed = self.chaos.seed;
                self.chaos = ChaosConfig { seed, ..ChaosConfig::parse(value)? };
            }
            "chaos_seed" => self.chaos.seed = num(key, value)?,
            "observability" => {
                self.observability = match value.trim() {
                    "true" | "on" => true,
                    "false" | "off" => false,
                    other => {
                        return Err(format!(
                            "bad value '{other}' for gateway key 'observability' \
                             (expected true/false)"
                        ))
                    }
                };
            }
            other => return Err(format!("unknown gateway config key '{other}'")),
        }
        Ok(())
    }

    /// Apply a TOML-subset document on top of `self`. Unknown keys are
    /// hard errors — a typo'd limit silently defaulting is exactly the
    /// failure a config file exists to prevent.
    pub fn apply_toml(&mut self, text: &str) -> Result<(), String> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') {
                if line == "[gateway]" {
                    continue;
                }
                return Err(format!(
                    "config line {}: unknown section '{line}' (only [gateway] is recognized)",
                    lineno + 1
                ));
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("config line {}: expected 'key = value'", lineno + 1))?;
            let value = toml_scalar(value)
                .map_err(|e| format!("config line {}: {e}", lineno + 1))?;
            self.set(key.trim(), &value)
                .map_err(|e| format!("config line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Apply `STOCATOR_GATEWAY_*` overrides. The lookup function is
    /// injected so tests can run in parallel without mutating process
    /// environment; production callers pass [`GatewayConfig::apply_env`].
    pub fn apply_env_with(
        &mut self,
        get: impl Fn(&str) -> Option<String>,
    ) -> Result<(), String> {
        const KEYS: &[&str] = &[
            "mode",
            "max_conns",
            "rate_limit",
            "burst",
            "auth_token",
            "read_timeout_ms",
            "drain_timeout_ms",
            "chaos",
            "chaos_seed",
            "observability",
        ];
        for key in KEYS {
            let var = format!("STOCATOR_GATEWAY_{}", key.to_ascii_uppercase());
            if let Some(value) = get(&var) {
                self.set(key, &value).map_err(|e| format!("{var}: {e}"))?;
            }
        }
        Ok(())
    }

    /// Apply overrides from the real process environment.
    pub fn apply_env(&mut self) -> Result<(), String> {
        self.apply_env_with(|k| std::env::var(k).ok())
    }

    /// Read and apply a TOML config file.
    pub fn apply_file(&mut self, path: &Path) -> Result<(), String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read gateway config {}: {e}", path.display()))?;
        self.apply_toml(&text)
    }

    /// One-line human summary for the `serve` banner.
    pub fn describe(&self) -> String {
        format!(
            "{} core, max-conns {}, rate-limit {}, auth {}, chaos {}, obs {}",
            self.mode.name(),
            self.max_conns,
            if self.rate_limit > 0.0 {
                format!("{}/s (burst {})", self.rate_limit, self.burst)
            } else {
                "off".to_string()
            },
            if self.auth_token.is_some() { "bearer" } else { "off" },
            self.chaos.spec(),
            if self.observability { "on" } else { "off" },
        )
    }
}

/// Parse one TOML scalar: quoted string (with `\"` and `\\` escapes),
/// bare number, or bool. Trailing `# comments` are stripped outside
/// quotes.
fn toml_scalar(raw: &str) -> Result<String, String> {
    let raw = raw.trim();
    if let Some(rest) = raw.strip_prefix('"') {
        let mut out = String::new();
        let mut chars = rest.chars();
        loop {
            match chars.next() {
                None => return Err("unterminated string".into()),
                Some('"') => break,
                Some('\\') => match chars.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("unsupported escape '\\{:?}'", other)),
                },
                Some(c) => out.push(c),
            }
        }
        let tail = chars.as_str().trim();
        if !tail.is_empty() && !tail.starts_with('#') {
            return Err(format!("trailing garbage after string: '{tail}'"));
        }
        Ok(out)
    } else {
        let bare = match raw.find('#') {
            Some(i) => raw[..i].trim(),
            None => raw,
        };
        if bare.is_empty() {
            return Err("empty value".into());
        }
        Ok(bare.to_string())
    }
}

/// Token-bucket limiter: `burst` capacity, refilled at `rate`
/// tokens/second. One token admits one request; an empty bucket
/// yields the exact time until the next token, which becomes the
/// `Retry-After` the client sleeps on.
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

struct BucketState {
    tokens: f64,
    last_refill: Instant,
}

impl RateLimiter {
    pub fn new(rate: f64, burst: u32) -> Option<RateLimiter> {
        if rate <= 0.0 {
            return None;
        }
        Some(RateLimiter {
            rate,
            burst: f64::from(burst.max(1)),
            state: Mutex::new(BucketState { tokens: f64::from(burst.max(1)), last_refill: Instant::now() }),
        })
    }

    /// Try to admit one request now. `Err(secs)` is the time until a
    /// token will be available — the wire `Retry-After`.
    pub fn admit(&self) -> Result<(), f64> {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let now = Instant::now();
        let elapsed = now.duration_since(s.last_refill).as_secs_f64();
        s.last_refill = now;
        s.tokens = (s.tokens + elapsed * self.rate).min(self.burst);
        if s.tokens >= 1.0 {
            s.tokens -= 1.0;
            Ok(())
        } else {
            Err((1.0 - s.tokens) / self.rate)
        }
    }
}

/// How many request-id → response entries the gateway retains.
pub const REPLAY_CACHE_ENTRIES: usize = 256;

/// Bounded idempotent-replay cache: the server half of the retry
/// protocol that makes "connection died mid-response" recoverable.
///
/// The client stamps every mutating request with a unique
/// `x-request-id`; after routing, the gateway stores the serialized
/// response under that id. A duplicate id — which can only mean the
/// client never saw the first response and blindly re-sent — is
/// answered from the cache (with an `x-request-replayed: true`
/// marker) instead of being re-executed. That converts a non-idempotent
/// re-send (duplicate PUT reporting a spurious replace, duplicate
/// `complete` hitting NoSuchUpload, …) into an exact repeat of the
/// original answer.
///
/// Correctness rules:
///
/// * **Only executed responses are cached.** Screening rejections
///   (`401`/`403`/`429`/shed `503`) provably never executed, and the
///   client retries those with the *same* id — caching one would
///   replay the rejection forever instead of letting the retry reach
///   the router.
/// * **Ids must be unique per logical operation.** The client draws
///   128-bit ids from a per-backend PCG32 stream and reuses one id
///   only across wire re-sends of the same operation.
/// * **Eviction is LRU over [`REPLAY_CACHE_ENTRIES`] entries.** An
///   entry is dropped only after that many *newer* stamped responses,
///   and both lookups and re-stores refresh recency. The client's
///   retry budget spans milliseconds-to-seconds and far fewer than 256
///   intervening stamped requests from one client, so an id is never
///   evicted while its operation can still be retried. A hit after
///   eviction is impossible (the id is gone); a re-send after eviction
///   re-executes — which is why the cache must comfortably outlive the
///   retry window, not why it must be unbounded.
pub struct ReplayCache {
    cap: usize,
    /// LRU queue, most recently used at the back. 256 entries makes a
    /// linear scan cheaper than any fancier index.
    entries: Mutex<VecDeque<(String, Vec<u8>)>>,
    hits: AtomicU64,
}

impl ReplayCache {
    pub fn new(cap: usize) -> ReplayCache {
        ReplayCache { cap: cap.max(1), entries: Mutex::new(VecDeque::new()), hits: AtomicU64::new(0) }
    }

    /// The serialized response previously stored under `id`, if any.
    /// A hit refreshes the entry's recency and counts as a replay.
    pub fn lookup(&self, id: &str) -> Option<Vec<u8>> {
        let mut q = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        let pos = q.iter().position(|(k, _)| k == id)?;
        let entry = q.remove(pos).expect("position came from this queue");
        let bytes = entry.1.clone();
        q.push_back(entry);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(bytes)
    }

    /// Remember `bytes` as the response for `id`, evicting the least
    /// recently used entry past capacity.
    pub fn store(&self, id: &str, bytes: Vec<u8>) {
        let mut q = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(pos) = q.iter().position(|(k, _)| k == id) {
            q.remove(pos);
        }
        q.push_back((id.to_string(), bytes));
        while q.len() > self.cap {
            q.pop_front();
        }
    }

    /// How many responses were served from the cache.
    pub fn replayed(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Entries currently resident (`<= capacity()`). Scrape-path only.
    pub fn occupancy(&self) -> usize {
        self.entries.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// The LRU bound this cache was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.occupancy()
    }
}

/// The screening plane shared by both server cores: auth, rate limit,
/// the idempotent-replay cache, the wire chaos plane, and rejection
/// counters (observability for tests, the CLI, and `/metricz`).
pub struct Gatekeeper {
    pub cfg: GatewayConfig,
    limiter: Option<RateLimiter>,
    /// Request-id replay cache — always on; with no stamped requests it
    /// is simply never consulted.
    pub replay: ReplayCache,
    chaos: Option<ChaosPlan>,
    rejected_429: AtomicU64,
    rejected_auth: AtomicU64,
    shed_503: AtomicU64,
    /// Per-[`crate::metrics::OpKind`] counts of *executed* store requests
    /// (screened rejections and replays never reach the store, so they
    /// are not ops). Same lock-free atomic array the store front end
    /// uses; snapshotted by the `/metricz` route.
    pub ops: LiveCounters,
    /// The end-to-end observability plane: per-op-class latency/byte
    /// histograms, phase splits, reactor sweep stats, and the `/tracez`
    /// ring (see [`crate::metrics::registry`]). Always constructed;
    /// `cfg.observability = false` disables *recording* while the
    /// scrape routes keep answering (with empty series).
    pub obs: ObsPlane,
}

impl Gatekeeper {
    pub fn new(cfg: GatewayConfig) -> Gatekeeper {
        let limiter = RateLimiter::new(cfg.rate_limit, cfg.burst);
        let chaos = cfg.chaos.is_active().then(|| ChaosPlan::new(cfg.chaos));
        let obs = ObsPlane::new(cfg.observability);
        Gatekeeper {
            cfg,
            limiter,
            replay: ReplayCache::new(REPLAY_CACHE_ENTRIES),
            chaos,
            rejected_429: AtomicU64::new(0),
            rejected_auth: AtomicU64::new(0),
            shed_503: AtomicU64::new(0),
            ops: LiveCounters::new(),
            obs,
        }
    }

    /// Should this freshly accepted connection be dropped on the floor
    /// (the `reset` chaos fault)? Always `false` with chaos off.
    pub fn chaos_at_accept(&self) -> bool {
        self.chaos.as_ref().is_some_and(ChaosPlan::at_accept)
    }

    /// What the chaos plane does to the response about to be written.
    pub fn chaos_on_response(&self) -> ChaosAction {
        self.chaos.as_ref().map_or(ChaosAction::None, ChaosPlan::on_response)
    }

    /// Total wire faults injected (all four kinds).
    pub fn chaos_injected(&self) -> u64 {
        self.chaos.as_ref().map_or(0, |c| c.injected.load(Ordering::Relaxed))
    }

    /// Per-kind wire fault injection counts, [`CHAOS_KINDS`] order.
    /// All zero with chaos off.
    pub fn chaos_injected_by_kind(&self) -> [u64; 4] {
        self.chaos.as_ref().map_or([0; 4], |c| {
            std::array::from_fn(|i| c.by_kind[i].load(Ordering::Relaxed))
        })
    }

    /// Screen one fully parsed request before routing. `Some(resp)`
    /// means the request is rejected without ever executing; `None`
    /// means it proceeds to the router. Order matters: auth before
    /// rate limit, so an attacker without a token cannot drain the
    /// bucket, and `/healthz` before both.
    pub fn screen(&self, req: &Request) -> Option<Response> {
        if req.path.trim_matches('/') == "healthz" {
            return None;
        }
        if let Some(expected) = &self.cfg.auth_token {
            let supplied = req
                .headers
                .get("authorization")
                .and_then(|v| v.trim().strip_prefix("Bearer "))
                .map(str::trim);
            match supplied {
                None => {
                    self.rejected_auth.fetch_add(1, Ordering::Relaxed);
                    return Some(
                        Response::new(401)
                            .with_header("WWW-Authenticate", "Bearer")
                            .with_header("x-error-kind", "unauthorized"),
                    );
                }
                Some(got) if got != expected => {
                    self.rejected_auth.fetch_add(1, Ordering::Relaxed);
                    return Some(Response::new(403).with_header("x-error-kind", "forbidden"));
                }
                Some(_) => {}
            }
        }
        if let Some(limiter) = &self.limiter {
            if let Err(after) = limiter.admit() {
                self.rejected_429.fetch_add(1, Ordering::Relaxed);
                return Some(
                    Response::new(429)
                        .with_header("Retry-After", format_retry_after(after))
                        .with_header("x-error-kind", "throttled"),
                );
            }
        }
        None
    }

    /// The response written to a connection shed at the cap, before
    /// any request is read — so the client knows nothing executed.
    pub fn overloaded(&self) -> Response {
        self.shed_503.fetch_add(1, Ordering::Relaxed);
        Response::new(503)
            .with_header("Retry-After", "0.05")
            .with_header("x-error-kind", "over-capacity")
    }

    pub fn rejected_429s(&self) -> u64 {
        self.rejected_429.load(Ordering::Relaxed)
    }

    pub fn rejected_auths(&self) -> u64 {
        self.rejected_auth.load(Ordering::Relaxed)
    }

    pub fn shed_503s(&self) -> u64 {
        self.shed_503.load(Ordering::Relaxed)
    }
}

/// Fractional delta-seconds with enough precision that sub-millisecond
/// refill times still round-trip as a positive sleep. (We control both
/// wire ends; the client also parses integer-seconds per RFC 9110.)
fn format_retry_after(secs: f64) -> String {
    format!("{:.4}", secs.max(0.0001))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_threaded_and_unlimited() {
        let cfg = GatewayConfig::default();
        assert_eq!(cfg.mode, GatewayMode::Threaded);
        assert_eq!(cfg.rate_limit, 0.0);
        assert!(cfg.auth_token.is_none());
        assert_eq!(GatewayConfig::serve_default().mode, GatewayMode::Reactor);
    }

    #[test]
    fn toml_subset_round_trips_every_key() {
        let mut cfg = GatewayConfig::default();
        cfg.apply_toml(
            r#"
            # gateway smoke config
            [gateway]
            mode = "reactor"       # event loop
            max_conns = 4096
            rate_limit = 1500.0
            burst = 128
            auth_token = "s3cr#t"  # hash inside quotes survives
            read_timeout_ms = 250
            drain_timeout_ms = 750
            chaos = "kill-response@p=0.02,truncate@p=0.01"
            chaos_seed = 99
            observability = false
            "#,
        )
        .expect("valid config must parse");
        assert_eq!(cfg.mode, GatewayMode::Reactor);
        assert_eq!(cfg.max_conns, 4096);
        assert_eq!(cfg.rate_limit, 1500.0);
        assert_eq!(cfg.burst, 128);
        assert_eq!(cfg.auth_token.as_deref(), Some("s3cr#t"));
        assert_eq!(cfg.read_timeout, Duration::from_millis(250));
        assert_eq!(cfg.drain_timeout, Duration::from_millis(750));
        assert_eq!(cfg.chaos.kill_response, 0.02);
        assert_eq!(cfg.chaos.truncate, 0.01);
        assert_eq!(cfg.chaos.seed, 99);
        assert!(!cfg.observability);
        assert!(cfg.describe().contains("obs off"));
        assert!(GatewayConfig::default().observability, "observability defaults on");
        assert!(GatewayConfig::default().describe().contains("obs on"));
        // Env layer knows the key too, and garbage is a startup error.
        cfg.apply_env_with(|k| {
            (k == "STOCATOR_GATEWAY_OBSERVABILITY").then(|| "on".to_string())
        })
        .unwrap();
        assert!(cfg.observability);
        assert!(cfg.set("observability", "maybe").is_err());
    }

    #[test]
    fn chaos_spec_parses_canonicalizes_and_rejects_garbage() {
        let c = ChaosConfig::parse("kill-response@p=0.25,truncate@p=0.1,stall@p=0.01,reset@p=1")
            .expect("full grammar parses");
        assert_eq!(c.kill_response, 0.25);
        assert_eq!(c.truncate, 0.1);
        assert_eq!(c.stall, 0.01);
        assert_eq!(c.reset, 1.0);
        assert!(c.is_active());
        assert_eq!(ChaosConfig::parse(&c.spec()).expect("spec round-trips"), c);
        // Empty / "off" / all-zero probabilities are all chaos-off.
        assert!(!ChaosConfig::parse("").unwrap().is_active());
        assert!(!ChaosConfig::parse("off").unwrap().is_active());
        let zero = ChaosConfig::parse("kill-response@p=0,reset@p=0.0").unwrap();
        assert!(!zero.is_active());
        assert_eq!(zero.spec(), "off");
        for bad in [
            "kill@p=0.5",          // unknown fault name
            "kill-response=0.5",   // missing @p=
            "truncate@p=1.5",      // out of range
            "stall@p=-0.1",        // negative
            "reset@p=lots",        // not a number
        ] {
            assert!(ChaosConfig::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        // A chaos-seed layer applied before the spec survives re-parsing.
        let mut cfg = GatewayConfig::default();
        cfg.set("chaos_seed", "42").unwrap();
        cfg.set("chaos", "reset@p=0.5").unwrap();
        assert_eq!(cfg.chaos.seed, 42);
        assert_eq!(cfg.chaos.reset, 0.5);
        assert!(cfg.describe().contains("chaos reset@p=0.5"));
        assert!(GatewayConfig::default().describe().contains("chaos off"));
    }

    #[test]
    fn replay_cache_replays_lru_evicts_and_counts_hits() {
        let cache = ReplayCache::new(3);
        assert!(cache.lookup("a").is_none(), "miss on an empty cache");
        cache.store("a", b"resp-a".to_vec());
        cache.store("b", b"resp-b".to_vec());
        cache.store("c", b"resp-c".to_vec());
        assert_eq!(cache.lookup("a").as_deref(), Some(&b"resp-a"[..]));
        // "a" was just refreshed, so inserting "d" evicts "b" (the LRU).
        cache.store("d", b"resp-d".to_vec());
        assert_eq!(cache.len(), 3);
        assert!(cache.lookup("b").is_none(), "LRU entry must be evicted");
        assert_eq!(cache.lookup("a").as_deref(), Some(&b"resp-a"[..]));
        assert_eq!(cache.lookup("d").as_deref(), Some(&b"resp-d"[..]));
        // Re-storing an id replaces its payload in place.
        cache.store("c", b"resp-c2".to_vec());
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.lookup("c").as_deref(), Some(&b"resp-c2"[..]));
        assert_eq!(cache.replayed(), 4, "misses never count as replays");
    }

    #[test]
    fn chaos_plane_draws_are_seeded_and_counted() {
        let gate = |seed| {
            Gatekeeper::new(GatewayConfig {
                chaos: ChaosConfig { kill_response: 0.5, reset: 0.5, seed, ..ChaosConfig::default() },
                ..GatewayConfig::default()
            })
        };
        let draws = |g: &Gatekeeper| -> (Vec<ChaosAction>, Vec<bool>) {
            (
                (0..64).map(|_| g.chaos_on_response()).collect(),
                (0..64).map(|_| g.chaos_at_accept()).collect(),
            )
        };
        let (r1, a1) = draws(&gate(11));
        let (r2, a2) = draws(&gate(11));
        assert_eq!(r1, r2, "same seed, same fault sequence");
        assert_eq!(a1, a2);
        assert!(r1.iter().any(|&x| x == ChaosAction::KillResponse));
        assert!(r1.iter().any(|&x| x == ChaosAction::None));
        assert!(a1.iter().any(|&x| x));
        let g = gate(11);
        let _ = draws(&g);
        assert!(g.chaos_injected() >= 1);
        // Per-kind counters partition the aggregate: only the armed
        // kinds fired, and their sum is the total.
        let by_kind = g.chaos_injected_by_kind();
        assert_eq!(by_kind.iter().sum::<u64>(), g.chaos_injected());
        assert!(by_kind[0] >= 1, "kill-response armed at p=0.5 must fire in 64 draws");
        assert!(by_kind[3] >= 1, "reset armed at p=0.5 must fire in 64 accepts");
        assert_eq!(by_kind[1], 0, "truncate was not armed");
        assert_eq!(by_kind[2], 0, "stall was not armed");
        // Chaos off: no plan, no draws, nothing injected.
        let off = Gatekeeper::new(GatewayConfig::default());
        assert_eq!(off.chaos_on_response(), ChaosAction::None);
        assert!(!off.chaos_at_accept());
        assert_eq!(off.chaos_injected(), 0);
        assert_eq!(off.chaos_injected_by_kind(), [0; 4]);
    }

    #[test]
    fn toml_rejects_unknown_keys_and_bad_values() {
        let mut cfg = GatewayConfig::default();
        assert!(cfg.apply_toml("max_cons = 5").is_err(), "typo'd key must be fatal");
        assert!(cfg.apply_toml("max_conns = many").is_err());
        assert!(cfg.apply_toml("max_conns = 0").is_err());
        assert!(cfg.apply_toml("rate_limit = -1").is_err());
        assert!(cfg.apply_toml("auth_token = \"unterminated").is_err());
        assert!(cfg.apply_toml("[server]").is_err());
    }

    #[test]
    fn env_overrides_beat_file_values() {
        let mut cfg = GatewayConfig::default();
        cfg.apply_toml("max_conns = 100\nmode = \"threaded\"").unwrap();
        cfg.apply_env_with(|k| match k {
            "STOCATOR_GATEWAY_MAX_CONNS" => Some("200".into()),
            "STOCATOR_GATEWAY_MODE" => Some("reactor".into()),
            "STOCATOR_GATEWAY_AUTH_TOKEN" => Some("tok".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(cfg.max_conns, 200);
        assert_eq!(cfg.mode, GatewayMode::Reactor);
        assert_eq!(cfg.auth_token.as_deref(), Some("tok"));
        // A bad env value is a startup error, not a silent default.
        assert!(cfg
            .apply_env_with(|k| (k == "STOCATOR_GATEWAY_BURST").then(|| "zero".to_string()))
            .is_err());
    }

    #[test]
    fn token_bucket_admits_burst_then_throttles_with_positive_retry_after() {
        let limiter = RateLimiter::new(10.0, 3).expect("positive rate builds a limiter");
        assert!(limiter.admit().is_ok());
        assert!(limiter.admit().is_ok());
        assert!(limiter.admit().is_ok());
        let after = limiter.admit().expect_err("burst exhausted");
        assert!(after > 0.0 && after <= 0.1 + 1e-6, "retry-after ~1 token at 10/s, got {after}");
        assert!(RateLimiter::new(0.0, 3).is_none(), "rate 0 disables the limiter");
    }

    #[test]
    fn gatekeeper_screens_auth_then_rate_and_exempts_healthz() {
        let gate = Gatekeeper::new(GatewayConfig {
            auth_token: Some("open-sesame".into()),
            rate_limit: 1000.0,
            burst: 2,
            ..GatewayConfig::default()
        });
        let req = |path: &str, auth: Option<&str>| {
            let mut r = Request {
                method: "GET".into(),
                path: path.into(),
                query: String::new(),
                headers: crate::gateway::http::Headers::new(),
                body: Vec::new(),
            };
            if let Some(a) = auth {
                r.headers.push("Authorization", a);
            }
            r
        };
        let missing = gate.screen(&req("/v1/c/k", None)).expect("no token -> rejected");
        assert_eq!(missing.status, 401);
        assert_eq!(missing.headers.get("x-error-kind"), Some("unauthorized"));
        let wrong = gate.screen(&req("/v1/c/k", Some("Bearer nope"))).expect("bad token");
        assert_eq!(wrong.status, 403);
        assert_eq!(gate.rejected_auths(), 2);
        // Correct token: burst of 2 admits, third gets a parseable 429.
        let ok = Some("Bearer open-sesame");
        assert!(gate.screen(&req("/v1/c/k", ok)).is_none());
        assert!(gate.screen(&req("/v1/c/k", ok)).is_none());
        let throttled = gate.screen(&req("/v1/c/k", ok)).expect("bucket empty");
        assert_eq!(throttled.status, 429);
        let after: f64 = throttled
            .headers
            .get("retry-after")
            .expect("429 carries Retry-After")
            .parse()
            .expect("Retry-After parses as f64");
        assert!(after > 0.0);
        assert_eq!(gate.rejected_429s(), 1);
        // /healthz bypasses both auth and the limiter even when drained.
        assert!(gate.screen(&req("/healthz", None)).is_none());
    }
}
