//! Gateway configuration and the production control plane.
//!
//! [`GatewayConfig`] is resolved in strictly increasing precedence:
//! built-in defaults → TOML config file (`--config PATH`) →
//! `STOCATOR_GATEWAY_*` environment variables → explicit CLI flags.
//! The TOML reader is a deliberate std-only subset (one `key = value`
//! per line, `#` comments, an optional `[gateway]` section header) —
//! enough for a service config file without pulling in a parser crate.
//!
//! [`Gatekeeper`] is the part of the production plane that is shared
//! verbatim by both server cores (threaded and reactor): bearer-token
//! auth (`401` missing / `403` mismatch) and a token-bucket rate
//! limiter that emits *real* `429 Too Many Requests` with a
//! fractional-seconds `Retry-After` the client honors. `/healthz` is
//! exempt from both so readiness probes and idle keep-alive holders
//! never consume quota. Screening happens after a request is fully
//! parsed but before it is routed, so a `429`/`401`/`403` provably
//! never executed — which is what makes the client's blind re-send
//! safe for every verb, mutating ones included.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::http::{Request, Response};

/// Which connection-handling core the gateway runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatewayMode {
    /// Legacy thread-per-connection core (PR 5). Library default, so
    /// `GatewayServer::bind` keeps its original behavior byte-for-byte.
    Threaded,
    /// Single-threaded non-blocking event loop (`gateway::reactor`).
    /// Default for the `serve` CLI.
    Reactor,
}

impl GatewayMode {
    pub fn parse(s: &str) -> Result<GatewayMode, String> {
        match s.trim() {
            "threaded" => Ok(GatewayMode::Threaded),
            "reactor" => Ok(GatewayMode::Reactor),
            other => Err(format!(
                "unknown gateway mode '{other}' (expected 'reactor' or 'threaded')"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GatewayMode::Threaded => "threaded",
            GatewayMode::Reactor => "reactor",
        }
    }
}

/// Resolved gateway configuration. See the module docs for precedence.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayConfig {
    pub mode: GatewayMode,
    /// Hard cap on simultaneous connections; excess accepts are shed
    /// with an immediate `503` + `x-error-kind: over-capacity`.
    pub max_conns: usize,
    /// Sustained request rate in requests/second; `0.0` disables the
    /// limiter entirely (the default — conformance stays byte-identical).
    pub rate_limit: f64,
    /// Token-bucket capacity: how many requests may burst above the
    /// sustained rate before `429`s start.
    pub burst: u32,
    /// When set, every non-`/healthz` request must carry
    /// `Authorization: Bearer <token>`.
    pub auth_token: Option<String>,
    /// Slow-loris guard: a connection holding a *partial* request this
    /// long with no progress gets `408` and is closed. Idle keep-alive
    /// connections (empty input buffer) are never reaped.
    pub read_timeout: Duration,
    /// Graceful-shutdown budget: in-flight requests get this long to
    /// finish before the reactor gives up and returns.
    pub drain_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            mode: GatewayMode::Threaded,
            max_conns: 16_384,
            rate_limit: 0.0,
            burst: 64,
            auth_token: None,
            read_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(2),
        }
    }
}

impl GatewayConfig {
    /// Defaults for the `serve` CLI: same as [`Default`] but the
    /// reactor core, so new deployments get the scalable path while
    /// the library entry point stays backward compatible.
    pub fn serve_default() -> Self {
        GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() }
    }

    /// Set one configuration key from its string form. Shared by the
    /// TOML reader, the env-var layer, and the CLI so all three agree
    /// on names, parsing, and validation.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String> {
            value
                .trim()
                .parse::<T>()
                .map_err(|_| format!("bad value '{value}' for gateway key '{key}'"))
        }
        match key {
            "mode" => self.mode = GatewayMode::parse(value)?,
            "max_conns" => {
                self.max_conns = num::<usize>(key, value)?;
                if self.max_conns == 0 {
                    return Err("max_conns must be >= 1".into());
                }
            }
            "rate_limit" => {
                self.rate_limit = num::<f64>(key, value)?;
                if !self.rate_limit.is_finite() || self.rate_limit < 0.0 {
                    return Err(format!("rate_limit must be finite and >= 0, got '{value}'"));
                }
            }
            "burst" => {
                self.burst = num::<u32>(key, value)?;
                if self.burst == 0 {
                    return Err("burst must be >= 1".into());
                }
            }
            "auth_token" => {
                let t = value.trim();
                self.auth_token = if t.is_empty() { None } else { Some(t.to_string()) };
            }
            "read_timeout_ms" => self.read_timeout = Duration::from_millis(num(key, value)?),
            "drain_timeout_ms" => self.drain_timeout = Duration::from_millis(num(key, value)?),
            other => return Err(format!("unknown gateway config key '{other}'")),
        }
        Ok(())
    }

    /// Apply a TOML-subset document on top of `self`. Unknown keys are
    /// hard errors — a typo'd limit silently defaulting is exactly the
    /// failure a config file exists to prevent.
    pub fn apply_toml(&mut self, text: &str) -> Result<(), String> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') {
                if line == "[gateway]" {
                    continue;
                }
                return Err(format!(
                    "config line {}: unknown section '{line}' (only [gateway] is recognized)",
                    lineno + 1
                ));
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("config line {}: expected 'key = value'", lineno + 1))?;
            let value = toml_scalar(value)
                .map_err(|e| format!("config line {}: {e}", lineno + 1))?;
            self.set(key.trim(), &value)
                .map_err(|e| format!("config line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Apply `STOCATOR_GATEWAY_*` overrides. The lookup function is
    /// injected so tests can run in parallel without mutating process
    /// environment; production callers pass [`GatewayConfig::apply_env`].
    pub fn apply_env_with(
        &mut self,
        get: impl Fn(&str) -> Option<String>,
    ) -> Result<(), String> {
        const KEYS: &[&str] = &[
            "mode",
            "max_conns",
            "rate_limit",
            "burst",
            "auth_token",
            "read_timeout_ms",
            "drain_timeout_ms",
        ];
        for key in KEYS {
            let var = format!("STOCATOR_GATEWAY_{}", key.to_ascii_uppercase());
            if let Some(value) = get(&var) {
                self.set(key, &value).map_err(|e| format!("{var}: {e}"))?;
            }
        }
        Ok(())
    }

    /// Apply overrides from the real process environment.
    pub fn apply_env(&mut self) -> Result<(), String> {
        self.apply_env_with(|k| std::env::var(k).ok())
    }

    /// Read and apply a TOML config file.
    pub fn apply_file(&mut self, path: &Path) -> Result<(), String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read gateway config {}: {e}", path.display()))?;
        self.apply_toml(&text)
    }

    /// One-line human summary for the `serve` banner.
    pub fn describe(&self) -> String {
        format!(
            "{} core, max-conns {}, rate-limit {}, auth {}",
            self.mode.name(),
            self.max_conns,
            if self.rate_limit > 0.0 {
                format!("{}/s (burst {})", self.rate_limit, self.burst)
            } else {
                "off".to_string()
            },
            if self.auth_token.is_some() { "bearer" } else { "off" },
        )
    }
}

/// Parse one TOML scalar: quoted string (with `\"` and `\\` escapes),
/// bare number, or bool. Trailing `# comments` are stripped outside
/// quotes.
fn toml_scalar(raw: &str) -> Result<String, String> {
    let raw = raw.trim();
    if let Some(rest) = raw.strip_prefix('"') {
        let mut out = String::new();
        let mut chars = rest.chars();
        loop {
            match chars.next() {
                None => return Err("unterminated string".into()),
                Some('"') => break,
                Some('\\') => match chars.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("unsupported escape '\\{:?}'", other)),
                },
                Some(c) => out.push(c),
            }
        }
        let tail = chars.as_str().trim();
        if !tail.is_empty() && !tail.starts_with('#') {
            return Err(format!("trailing garbage after string: '{tail}'"));
        }
        Ok(out)
    } else {
        let bare = match raw.find('#') {
            Some(i) => raw[..i].trim(),
            None => raw,
        };
        if bare.is_empty() {
            return Err("empty value".into());
        }
        Ok(bare.to_string())
    }
}

/// Token-bucket limiter: `burst` capacity, refilled at `rate`
/// tokens/second. One token admits one request; an empty bucket
/// yields the exact time until the next token, which becomes the
/// `Retry-After` the client sleeps on.
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

struct BucketState {
    tokens: f64,
    last_refill: Instant,
}

impl RateLimiter {
    pub fn new(rate: f64, burst: u32) -> Option<RateLimiter> {
        if rate <= 0.0 {
            return None;
        }
        Some(RateLimiter {
            rate,
            burst: f64::from(burst.max(1)),
            state: Mutex::new(BucketState { tokens: f64::from(burst.max(1)), last_refill: Instant::now() }),
        })
    }

    /// Try to admit one request now. `Err(secs)` is the time until a
    /// token will be available — the wire `Retry-After`.
    pub fn admit(&self) -> Result<(), f64> {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let now = Instant::now();
        let elapsed = now.duration_since(s.last_refill).as_secs_f64();
        s.last_refill = now;
        s.tokens = (s.tokens + elapsed * self.rate).min(self.burst);
        if s.tokens >= 1.0 {
            s.tokens -= 1.0;
            Ok(())
        } else {
            Err((1.0 - s.tokens) / self.rate)
        }
    }
}

/// The screening plane shared by both server cores: auth, rate limit,
/// and rejection counters (observability for tests and the CLI).
pub struct Gatekeeper {
    pub cfg: GatewayConfig,
    limiter: Option<RateLimiter>,
    rejected_429: AtomicU64,
    rejected_auth: AtomicU64,
    shed_503: AtomicU64,
}

impl Gatekeeper {
    pub fn new(cfg: GatewayConfig) -> Gatekeeper {
        let limiter = RateLimiter::new(cfg.rate_limit, cfg.burst);
        Gatekeeper { cfg, limiter, rejected_429: AtomicU64::new(0), rejected_auth: AtomicU64::new(0), shed_503: AtomicU64::new(0) }
    }

    /// Screen one fully parsed request before routing. `Some(resp)`
    /// means the request is rejected without ever executing; `None`
    /// means it proceeds to the router. Order matters: auth before
    /// rate limit, so an attacker without a token cannot drain the
    /// bucket, and `/healthz` before both.
    pub fn screen(&self, req: &Request) -> Option<Response> {
        if req.path.trim_matches('/') == "healthz" {
            return None;
        }
        if let Some(expected) = &self.cfg.auth_token {
            let supplied = req
                .headers
                .get("authorization")
                .and_then(|v| v.trim().strip_prefix("Bearer "))
                .map(str::trim);
            match supplied {
                None => {
                    self.rejected_auth.fetch_add(1, Ordering::Relaxed);
                    return Some(
                        Response::new(401)
                            .with_header("WWW-Authenticate", "Bearer")
                            .with_header("x-error-kind", "unauthorized"),
                    );
                }
                Some(got) if got != expected => {
                    self.rejected_auth.fetch_add(1, Ordering::Relaxed);
                    return Some(Response::new(403).with_header("x-error-kind", "forbidden"));
                }
                Some(_) => {}
            }
        }
        if let Some(limiter) = &self.limiter {
            if let Err(after) = limiter.admit() {
                self.rejected_429.fetch_add(1, Ordering::Relaxed);
                return Some(
                    Response::new(429)
                        .with_header("Retry-After", format_retry_after(after))
                        .with_header("x-error-kind", "throttled"),
                );
            }
        }
        None
    }

    /// The response written to a connection shed at the cap, before
    /// any request is read — so the client knows nothing executed.
    pub fn overloaded(&self) -> Response {
        self.shed_503.fetch_add(1, Ordering::Relaxed);
        Response::new(503)
            .with_header("Retry-After", "0.05")
            .with_header("x-error-kind", "over-capacity")
    }

    pub fn rejected_429s(&self) -> u64 {
        self.rejected_429.load(Ordering::Relaxed)
    }

    pub fn rejected_auths(&self) -> u64 {
        self.rejected_auth.load(Ordering::Relaxed)
    }

    pub fn shed_503s(&self) -> u64 {
        self.shed_503.load(Ordering::Relaxed)
    }
}

/// Fractional delta-seconds with enough precision that sub-millisecond
/// refill times still round-trip as a positive sleep. (We control both
/// wire ends; the client also parses integer-seconds per RFC 9110.)
fn format_retry_after(secs: f64) -> String {
    format!("{:.4}", secs.max(0.0001))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_threaded_and_unlimited() {
        let cfg = GatewayConfig::default();
        assert_eq!(cfg.mode, GatewayMode::Threaded);
        assert_eq!(cfg.rate_limit, 0.0);
        assert!(cfg.auth_token.is_none());
        assert_eq!(GatewayConfig::serve_default().mode, GatewayMode::Reactor);
    }

    #[test]
    fn toml_subset_round_trips_every_key() {
        let mut cfg = GatewayConfig::default();
        cfg.apply_toml(
            r#"
            # gateway smoke config
            [gateway]
            mode = "reactor"       # event loop
            max_conns = 4096
            rate_limit = 1500.0
            burst = 128
            auth_token = "s3cr#t"  # hash inside quotes survives
            read_timeout_ms = 250
            drain_timeout_ms = 750
            "#,
        )
        .expect("valid config must parse");
        assert_eq!(cfg.mode, GatewayMode::Reactor);
        assert_eq!(cfg.max_conns, 4096);
        assert_eq!(cfg.rate_limit, 1500.0);
        assert_eq!(cfg.burst, 128);
        assert_eq!(cfg.auth_token.as_deref(), Some("s3cr#t"));
        assert_eq!(cfg.read_timeout, Duration::from_millis(250));
        assert_eq!(cfg.drain_timeout, Duration::from_millis(750));
    }

    #[test]
    fn toml_rejects_unknown_keys_and_bad_values() {
        let mut cfg = GatewayConfig::default();
        assert!(cfg.apply_toml("max_cons = 5").is_err(), "typo'd key must be fatal");
        assert!(cfg.apply_toml("max_conns = many").is_err());
        assert!(cfg.apply_toml("max_conns = 0").is_err());
        assert!(cfg.apply_toml("rate_limit = -1").is_err());
        assert!(cfg.apply_toml("auth_token = \"unterminated").is_err());
        assert!(cfg.apply_toml("[server]").is_err());
    }

    #[test]
    fn env_overrides_beat_file_values() {
        let mut cfg = GatewayConfig::default();
        cfg.apply_toml("max_conns = 100\nmode = \"threaded\"").unwrap();
        cfg.apply_env_with(|k| match k {
            "STOCATOR_GATEWAY_MAX_CONNS" => Some("200".into()),
            "STOCATOR_GATEWAY_MODE" => Some("reactor".into()),
            "STOCATOR_GATEWAY_AUTH_TOKEN" => Some("tok".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(cfg.max_conns, 200);
        assert_eq!(cfg.mode, GatewayMode::Reactor);
        assert_eq!(cfg.auth_token.as_deref(), Some("tok"));
        // A bad env value is a startup error, not a silent default.
        assert!(cfg
            .apply_env_with(|k| (k == "STOCATOR_GATEWAY_BURST").then(|| "zero".to_string()))
            .is_err());
    }

    #[test]
    fn token_bucket_admits_burst_then_throttles_with_positive_retry_after() {
        let limiter = RateLimiter::new(10.0, 3).expect("positive rate builds a limiter");
        assert!(limiter.admit().is_ok());
        assert!(limiter.admit().is_ok());
        assert!(limiter.admit().is_ok());
        let after = limiter.admit().expect_err("burst exhausted");
        assert!(after > 0.0 && after <= 0.1 + 1e-6, "retry-after ~1 token at 10/s, got {after}");
        assert!(RateLimiter::new(0.0, 3).is_none(), "rate 0 disables the limiter");
    }

    #[test]
    fn gatekeeper_screens_auth_then_rate_and_exempts_healthz() {
        let gate = Gatekeeper::new(GatewayConfig {
            auth_token: Some("open-sesame".into()),
            rate_limit: 1000.0,
            burst: 2,
            ..GatewayConfig::default()
        });
        let req = |path: &str, auth: Option<&str>| {
            let mut r = Request {
                method: "GET".into(),
                path: path.into(),
                query: String::new(),
                headers: crate::gateway::http::Headers::new(),
                body: Vec::new(),
            };
            if let Some(a) = auth {
                r.headers.push("Authorization", a);
            }
            r
        };
        let missing = gate.screen(&req("/v1/c/k", None)).expect("no token -> rejected");
        assert_eq!(missing.status, 401);
        assert_eq!(missing.headers.get("x-error-kind"), Some("unauthorized"));
        let wrong = gate.screen(&req("/v1/c/k", Some("Bearer nope"))).expect("bad token");
        assert_eq!(wrong.status, 403);
        assert_eq!(gate.rejected_auths(), 2);
        // Correct token: burst of 2 admits, third gets a parseable 429.
        let ok = Some("Bearer open-sesame");
        assert!(gate.screen(&req("/v1/c/k", ok)).is_none());
        assert!(gate.screen(&req("/v1/c/k", ok)).is_none());
        let throttled = gate.screen(&req("/v1/c/k", ok)).expect("bucket empty");
        assert_eq!(throttled.status, 429);
        let after: f64 = throttled
            .headers
            .get("retry-after")
            .expect("429 carries Retry-After")
            .parse()
            .expect("Retry-After parses as f64");
        assert!(after > 0.0);
        assert_eq!(gate.rejected_429s(), 1);
        // /healthz bypasses both auth and the limiter even when drained.
        assert!(gate.screen(&req("/healthz", None)).is_none());
    }
}
