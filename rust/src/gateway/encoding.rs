//! Percent-encoding and query-string helpers shared by the gateway
//! server and the `HttpBackend` client.
//!
//! Object-store keys are flat names that may contain anything — spaces,
//! `%`, `/`, unicode — while URLs and header values may not. The rule
//! here is RFC 3986's strictest useful subset: everything outside the
//! unreserved set (`A–Z a–z 0–9 - . _ ~`) is `%XX`-encoded, *including*
//! `/`, so an entire key always travels as one opaque path segment and
//! the server never has to guess where a container ends and a key
//! begins. The output alphabet is also header-safe, so the same encoder
//! carries metadata keys/values in `x-object-meta-*` headers.

/// Percent-encode every byte outside the RFC 3986 unreserved set
/// (`/` included — a key is one path segment). Allocation-free per
/// byte: hex nibbles come from a lookup, not `format!` (every request
/// target and metadata header funnels through here).
pub fn pct_encode(s: &str) -> String {
    const HEX: &[u8; 16] = b"0123456789ABCDEF";
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        if b.is_ascii_alphanumeric() || matches!(b, b'-' | b'.' | b'_' | b'~') {
            out.push(b as char);
        } else {
            out.push('%');
            out.push(HEX[(b >> 4) as usize] as char);
            out.push(HEX[(b & 0xF) as usize] as char);
        }
    }
    out
}

/// Render one object-metadata pair as its `x-object-meta-*` wire header.
/// The single definition both the gateway server and `HttpBackend` use,
/// so the metadata round-trip cannot drift between the two ends.
pub fn meta_header(key: &str, value: &str) -> (String, String) {
    (format!("x-object-meta-{}", pct_encode(key)), pct_encode(value))
}

/// Strict inverse of [`pct_encode`]: `%XX` escapes decode, unreserved
/// bytes and literal `/` pass through (a client that left slashes bare
/// still round-trips), anything else — malformed escapes, raw control
/// bytes, invalid UTF-8 — is `None`.
pub fn pct_decode(s: &str) -> Option<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes.get(i + 1..i + 3)?;
                let hex = std::str::from_utf8(hex).ok()?;
                out.push(u8::from_str_radix(hex, 16).ok()?);
                i += 3;
            }
            b if b.is_ascii_graphic() || b >= 0x80 => {
                out.push(b);
                i += 1;
            }
            _ => return None,
        }
    }
    String::from_utf8(out).ok()
}

/// Render query pairs as `k=v&k2=v2`, both sides percent-encoded.
/// Empty input renders as an empty string (no `?`).
pub fn encode_query(pairs: &[(&str, String)]) -> String {
    pairs
        .iter()
        .map(|(k, v)| format!("{}={}", pct_encode(k), pct_encode(v)))
        .collect::<Vec<_>>()
        .join("&")
}

/// Parse a raw query string into decoded pairs; pairs that fail to
/// decode are dropped (a hostile querystring cannot poison routing).
pub fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
            Some((pct_decode(k)?, pct_decode(v)?))
        })
        .collect()
}

/// Look up a decoded query parameter.
pub fn query_param<'a>(pairs: &'a [(String, String)], name: &str) -> Option<&'a str> {
    pairs
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostile_names_roundtrip() {
        for name in [
            "",
            "plain",
            "a/b/c/part-0",
            "sp ace%and%percent",
            "uni-cöde-日本",
            "query?amp&eq=1",
            "plus+sign~tilde",
            "_temporary/0/_temporary/attempt_x/part-1",
        ] {
            let enc = pct_encode(name);
            assert!(
                enc.bytes()
                    .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'.' | b'_' | b'~' | b'%')),
                "{name} -> {enc} has unsafe bytes"
            );
            assert!(!enc.contains('/'), "{name} -> {enc}");
            assert_eq!(pct_decode(&enc).as_deref(), Some(name), "{name} -> {enc}");
        }
    }

    #[test]
    fn decode_accepts_literal_slashes_rejects_garbage() {
        assert_eq!(pct_decode("a/b").as_deref(), Some("a/b"));
        assert_eq!(pct_decode("a%2Fb").as_deref(), Some("a/b"));
        assert_eq!(pct_decode("%zz"), None);
        assert_eq!(pct_decode("a%2"), None);
        assert_eq!(pct_decode("a b"), None, "raw space is not valid in a URL");
    }

    #[test]
    fn query_roundtrip() {
        let q = encode_query(&[
            ("prefix", "d/part ".to_string()),
            ("marker", "d/part-0001".to_string()),
            ("limit", "10".to_string()),
        ]);
        let pairs = parse_query(&q);
        assert_eq!(query_param(&pairs, "prefix"), Some("d/part "));
        assert_eq!(query_param(&pairs, "marker"), Some("d/part-0001"));
        assert_eq!(query_param(&pairs, "limit"), Some("10"));
        assert_eq!(query_param(&pairs, "absent"), None);
        assert_eq!(parse_query(""), vec![]);
    }
}
