//! The gateway REST server: any [`Backend`] behind Swift/S3-style HTTP
//! routes on a std `TcpListener`.
//!
//! # Routes
//!
//! | Route | Backend call |
//! |---|---|
//! | `PUT /v1/{container}` | `create_container` (201 / 409) |
//! | `HEAD /v1/{container}` | `container_exists` (200 / 404) |
//! | `GET /v1/{container}?prefix=&marker=&limit=` | `list_page` (body: `name size etag` lines, `x-next-marker`) |
//! | `GET /v1/{container}?live=count\|bytes` | `live_count` / `live_bytes` |
//! | `PUT /v1/{container}/{key}` | `put` (201, `ETag`, `x-replaced`) |
//! | `GET /v1/{container}/{key}` [+`Range`] | `get` / `get_range` (200 / 206 / 416) |
//! | `HEAD /v1/{container}/{key}` | `head` (200, stat headers) |
//! | `DELETE /v1/{container}/{key}` | `delete` (204, final stat headers) |
//! | `POST /v1/{container}/{key}?uploads` | `initiate_multipart` (200, `x-upload-id`) |
//! | `PUT /v1-upload/{id}/{part}` | `upload_part` (201) |
//! | `POST /v1-upload/{id}?min-part-size=N` | `complete_multipart` (200, assembled body + target headers) |
//! | `DELETE /v1-upload/{id}` | `abort_multipart` (204) |
//! | `GET /v1-upload` | `multipart_in_flight` (200, body: count) |
//! | `GET`/`HEAD /healthz` | readiness probe (200 `ok`; no backend call) |
//! | `GET`/`HEAD /metricz` | Prometheus-style text exposition: gatekeeper rejections, per-[`OpKind`] store ops, serve-latency/byte/phase histograms, reactor sweep stats (no backend call, exempt from screening) |
//! | `GET`/`HEAD /tracez` | JSON ring of the last traced requests: per-phase nanoseconds, status, replay/chaos/429 disposition (exempt from screening) |
//!
//! Containers and keys travel percent-encoded ([`super::encoding`]);
//! object metadata rides as `x-object-meta-<pct-key>: <pct-value>`
//! headers, the virtual-clock creation instant as `x-sim-created-at`,
//! and every object response carries `ETag` (quoted 16-hex-digit FNV
//! tag) plus `x-object-size` (the FULL object size — the
//! `Content-Range` total — even on partial responses). Backend errors
//! map onto HTTP statuses with a machine-readable `x-error-kind` header
//! so [`super::client::HttpBackend`] can reconstruct the exact
//! [`BackendError`] without parsing prose.
//!
//! Two interchangeable connection cores serve these routes. The legacy
//! **threaded** core (one thread per connection, keep-alive until the
//! peer closes) is the library default, so `GatewayServer::bind` keeps
//! its PR 5 behavior byte-for-byte. The **reactor** core
//! ([`super::reactor`]) is a std-only non-blocking event loop — the
//! `serve` CLI default — for connection counts thread-per-connection
//! cannot reach. Both cores screen every parsed request through the
//! shared [`Gatekeeper`] (bearer auth, token-bucket 429s) and shed
//! accepts beyond `max_conns` with an immediate `503
//! x-error-kind: over-capacity`; with a default config the gatekeeper
//! admits everything, so conformance stays byte-identical. Concurrency
//! safety is the inner backend's contract (`Backend` is `Send + Sync`,
//! and its atomic-PUT guarantee is what makes concurrent gateway
//! clients safe).
//!
//! Both cores also share [`process_request`] — screen, then consult the
//! gatekeeper's request-id replay cache, then route — and apply the
//! wire chaos plane (`ChaosConfig`) at the connection layer when it is
//! armed: responses killed after a prefix, truncated inside the body,
//! stalled past the client's read deadline, or connections dropped at
//! accept. Chaos lives *below* routing, so an injected fault always
//! hits a request that already executed — exactly the ambiguity the
//! replay cache exists to resolve.

use super::config::{ChaosAction, Gatekeeper, GatewayConfig, GatewayMode, CHAOS_KINDS, STALL_HOLD};
use super::encoding::{meta_header, parse_query, pct_decode, pct_encode, query_param};
use super::http::{
    read_request, serialize_response, write_response, Request, Response, REQUEST_ID,
    REQUEST_REPLAYED,
};
use crate::metrics::histogram::{bucket_upper_nanos, Histogram};
use crate::metrics::registry::{PHASES, TRACE_RING_SLOTS, UNIT_SCALE};
use crate::metrics::{OpKind, PhaseNanos, TraceEntry};
use crate::objectstore::backend::{Backend, BackendError};
use crate::objectstore::object::{Metadata, Object};
use crate::simclock::SimInstant;
use crate::util::json::Json;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A bound-but-not-yet-serving gateway. Bind first (so callers learn
/// the ephemeral port), then [`GatewayServer::spawn`] or
/// [`GatewayServer::run`].
pub struct GatewayServer {
    listener: TcpListener,
    backend: Arc<dyn Backend>,
    gate: Arc<Gatekeeper>,
}

/// Handle to a spawned gateway: keeps the serving loop alive; stops it
/// on [`GatewayHandle::shutdown`] or drop.
pub struct GatewayHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    gate: Arc<Gatekeeper>,
    join: Option<JoinHandle<()>>,
}

impl GatewayServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) over
    /// `backend`, with the default config (threaded core, no limits).
    pub fn bind(addr: &str, backend: Arc<dyn Backend>) -> std::io::Result<Self> {
        Self::bind_with(addr, backend, GatewayConfig::default())
    }

    /// Bind with an explicit [`GatewayConfig`] (core selection,
    /// connection cap, rate limit, bearer auth, timeouts).
    pub fn bind_with(
        addr: &str,
        backend: Arc<dyn Backend>,
        config: GatewayConfig,
    ) -> std::io::Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
            backend,
            gate: Arc::new(Gatekeeper::new(config)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Serve on a background thread; the returned handle stops the
    /// server when shut down or dropped.
    pub fn spawn(self) -> GatewayHandle {
        let addr = self.local_addr();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let gate = self.gate.clone();
        let join = std::thread::spawn(move || self.serve(&stop2));
        GatewayHandle {
            addr,
            stop,
            gate,
            join: Some(join),
        }
    }

    /// Serve on the calling thread, forever (the `serve` subcommand).
    pub fn run(self) {
        self.serve(&AtomicBool::new(false));
    }

    fn serve(self, stop: &AtomicBool) {
        match self.gate.cfg.mode {
            GatewayMode::Threaded => self.accept_loop(stop),
            GatewayMode::Reactor => {
                super::reactor::run_loop(self.listener, self.backend, self.gate, stop)
            }
        }
    }

    fn accept_loop(self, stop: &AtomicBool) {
        let active = Arc::new(AtomicUsize::new(0));
        for conn in self.listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let Ok(stream) = conn else { continue };
            if self.gate.chaos_at_accept() {
                // `reset` chaos: drop the connection on the floor
                // before reading a byte — the peer sees EOF (or
                // ECONNRESET) with its request provably unexecuted.
                continue;
            }
            if active.load(Ordering::Relaxed) >= self.gate.cfg.max_conns {
                let gate = self.gate.clone();
                std::thread::spawn(move || shed_connection(stream, &gate));
                continue;
            }
            active.fetch_add(1, Ordering::Relaxed);
            let backend = self.backend.clone();
            let gate = self.gate.clone();
            let active = active.clone();
            // Detached per-connection thread: exits when the peer
            // closes (read returns EOF) or sends garbage.
            std::thread::spawn(move || {
                serve_connection(stream, &*backend, &gate);
                active.fetch_sub(1, Ordering::Relaxed);
            });
        }
    }
}

/// Refuse a connection accepted past `max_conns`: an immediate `503`
/// with `x-error-kind: over-capacity` and `Retry-After`, written before
/// any request byte is read — so the peer knows nothing executed and a
/// blind re-send is safe. Runs on a throwaway thread (both cores) so a
/// stalled peer cannot slow the accept path; the short post-write drain
/// keeps a close-with-unread-data RST from destroying the 503 in the
/// peer's receive buffer.
pub(crate) fn shed_connection(mut stream: TcpStream, gate: &Gatekeeper) {
    let resp = gate.overloaded();
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    if write_response(&mut stream, &resp).is_err() {
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut scratch = [0u8; 4096];
    use std::io::Read as _;
    while matches!(stream.read(&mut scratch), Ok(n) if n > 0) {}
}

impl GatewayHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `429`s the gatekeeper has emitted (observability for tests/CLI).
    pub fn throttled_429s(&self) -> u64 {
        self.gate.rejected_429s()
    }

    /// Connections shed at the cap with a `503`.
    pub fn shed_503s(&self) -> u64 {
        self.gate.shed_503s()
    }

    /// Requests rejected with `401`/`403`.
    pub fn rejected_auths(&self) -> u64 {
        self.gate.rejected_auths()
    }

    /// Responses served from the request-id replay cache.
    pub fn replayed_responses(&self) -> u64 {
        self.gate.replay.replayed()
    }

    /// Wire faults injected by the chaos plane (all kinds).
    pub fn chaos_injected(&self) -> u64 {
        self.gate.chaos_injected()
    }

    /// Stop accepting and join the accept loop. Established connections
    /// die with their client sockets.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept() call.
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
    }
}

impl Drop for GatewayHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Keep-alive request loop for one connection.
fn serve_connection(stream: TcpStream, backend: &dyn Backend, gate: &Gatekeeper) {
    use std::io::Write as _;
    let Ok(write_half) = stream.try_clone() else { return };
    let mut write_half = write_half;
    let mut reader = BufReader::new(stream);
    loop {
        let mut req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean close between requests
            Err(_) => {
                // Malformed request: answer 400 and drop the connection
                // (framing may be lost).
                let _ = write_response(&mut write_half, &Response::new(400));
                return;
            }
        };
        let outcome = process_request_traced(backend, gate, &mut req, 0, 0);
        let bytes = outcome.bytes;
        match gate.chaos_on_response() {
            ChaosAction::None => {
                if write_half.write_all(&bytes).is_err() {
                    return;
                }
            }
            ChaosAction::Stall => {
                // Hold the response unwritten past the client's read
                // deadline, then close without sending a byte.
                if let Some(token) = outcome.trace {
                    gate.obs.trace.patch_disposition(token, chaos_disposition(ChaosAction::Stall));
                }
                std::thread::sleep(STALL_HOLD);
                return;
            }
            action => {
                // Kill/truncate: write a strict prefix, then close —
                // the peer reads a genuinely torn response.
                if let Some(token) = outcome.trace {
                    gate.obs.trace.patch_disposition(token, chaos_disposition(action));
                }
                let cut = chaos_cut(action, bytes.len());
                let _ = write_half.write_all(&bytes[..cut]);
                return;
            }
        }
    }
}

/// Screen → replay → route: produce the exact wire bytes answering one
/// request. Shared by both cores so replay semantics are identical.
///
/// Screening rejections (`401`/`403`/`429`) are never cached: they are
/// provably unexecuted, and the client re-sends them under the *same*
/// request id — a cached `429` would replay forever instead of letting
/// the retry reach the router. Executed responses to stamped requests
/// are stored (with the [`REQUEST_REPLAYED`] marker pre-applied to the
/// stored copy) *before* any byte is written, so a response the chaos
/// plane kills mid-write is already replayable.
pub(crate) fn process_request(
    backend: &dyn Backend,
    gate: &Gatekeeper,
    req: &mut Request,
) -> Vec<u8> {
    process_request_traced(backend, gate, req, 0, 0).bytes
}

/// What serving one request produced: the wire bytes, plus the trace
/// token the connection layer uses to patch a chaos disposition into
/// the `/tracez` entry after the wire decision (`None` for probe
/// routes, dropped traces, or observability off).
pub(crate) struct ServeOutcome {
    pub bytes: Vec<u8>,
    pub trace: Option<(usize, u64)>,
}

/// [`process_request`] with core-measured phase timings attached:
/// `queue_nanos` is the reactor sweep's dispatch delay and
/// `parse_nanos` the wire-parse time (both 0 on the threaded core,
/// where parsing is entangled with the blocking socket wait). The
/// screen/route/serialize phases are measured here; recording happens
/// only with the observability plane enabled and is wait-free
/// (relaxed atomics plus a `try_lock` trace-slot write).
pub(crate) fn process_request_traced(
    backend: &dyn Backend,
    gate: &Gatekeeper,
    req: &mut Request,
    queue_nanos: u64,
    parse_nanos: u64,
) -> ServeOutcome {
    // Probe routes, exempt from auth/rate-limit (both cores reach them
    // through this shared path) — and never traced or counted
    // themselves, so a scrape cannot move what it measures.
    match req.path.trim_matches('/') {
        "healthz" => {
            // Liveness/readiness: answering at all proves the accept
            // loop, connection thread and router are up. Load drivers
            // poll this instead of sleeping after spawn.
            let resp = match req.method.as_str() {
                "GET" => probe_response(Response::new(200).with_body(b"ok".to_vec()), "text/plain"),
                "HEAD" => probe_response(Response::new(200), "text/plain"),
                m => method_not_allowed("/healthz", m),
            };
            return ServeOutcome { bytes: serialize_response(&resp), trace: None };
        }
        "metricz" => {
            return ServeOutcome {
                bytes: serialize_response(&metricz_response(gate, &req.method)),
                trace: None,
            }
        }
        "tracez" => {
            return ServeOutcome {
                bytes: serialize_response(&tracez_response(gate, &req.method)),
                trace: None,
            }
        }
        _ => {}
    }
    let obs = gate.obs.enabled();
    let mut phases = PhaseNanos {
        queue: queue_nanos,
        parse: parse_nanos,
        ..PhaseNanos::default()
    };
    // Copies for the trace entry: `route` consumes the path, so they
    // must be taken up front (only when the plane records at all).
    let trace_ctx = obs.then(|| (req.method.clone(), req.path.clone()));
    let request_id = req.headers.get(REQUEST_ID).map(str::to_string);

    let t = obs.then(Instant::now);
    let screened = gate.screen(req);
    phases.screen = t.map_or(0, elapsed_nanos);
    if let Some(rejection) = screened {
        let disposition = if rejection.status == 429 { "rejected-429" } else { "rejected-auth" };
        let status = rejection.status;
        let bytes = serialize_response(&rejection);
        let trace = trace_ctx.and_then(|(method, path)| {
            push_trace(gate, &request_id, method, path, status, None, phases, disposition)
        });
        return ServeOutcome { bytes, trace };
    }
    if let Some(id) = &request_id {
        if let Some(bytes) = gate.replay.lookup(id) {
            let trace = trace_ctx.and_then(|(method, path)| {
                push_trace(gate, &request_id, method, path, wire_status(&bytes), None, phases, "replayed")
            });
            return ServeOutcome { bytes, trace };
        }
    }
    // Classify before routing: `route` consumes the path and may move
    // the body out of the request.
    let op = classify_op(&req.method, &req.path, &req.query);
    let body_len = req.body.len() as u64;
    let t = obs.then(Instant::now);
    let mut resp = route(backend, req);
    phases.route = t.map_or(0, elapsed_nanos);
    if let Some(kind) = op {
        // Mirror the store front end's accounting rules: every executed
        // request is an op (404s included); bytes move only on success.
        gate.ops.record_op(kind);
        match kind {
            OpKind::GetObject if matches!(resp.status, 200 | 206) => {
                gate.ops.record_read(resp.body.len() as u64);
            }
            OpKind::PutObject if resp.status == 201 => {
                gate.ops.record_write(body_len);
            }
            _ => {}
        }
    }
    let status = resp.status;
    let t = obs.then(Instant::now);
    let bytes = serialize_response(&resp);
    if let Some(id) = &request_id {
        resp.headers.push(REQUEST_REPLAYED, "true");
        gate.replay.store(id, serialize_response(&resp));
    }
    phases.serialize = t.map_or(0, elapsed_nanos);
    let trace = trace_ctx.and_then(|(method, path)| {
        if let Some(kind) = op {
            gate.obs.requests.record(kind, body_len, bytes.len() as u64, &phases);
        }
        push_trace(gate, &request_id, method, path, status, op, phases, "ok")
    });
    ServeOutcome { bytes, trace }
}

/// Nanoseconds since `since`, saturating.
pub(crate) fn elapsed_nanos(since: Instant) -> u64 {
    since.elapsed().as_nanos().min(u64::MAX as u128) as u64
}

/// Status code of an already-serialized response (`HTTP/1.1 NNN ...`);
/// how a replayed trace entry learns the status it re-served.
fn wire_status(bytes: &[u8]) -> u16 {
    bytes
        .get(9..12)
        .and_then(|s| std::str::from_utf8(s).ok())
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// The `/tracez` disposition label for a chaos action applied at the
/// connection layer (patched into the entry after the wire decision).
pub(crate) fn chaos_disposition(action: ChaosAction) -> &'static str {
    match action {
        ChaosAction::KillResponse => "chaos-kill-response",
        ChaosAction::Truncate => "chaos-truncate",
        ChaosAction::Stall => "chaos-stall",
        ChaosAction::None => "ok",
    }
}

#[allow(clippy::too_many_arguments)]
fn push_trace(
    gate: &Gatekeeper,
    id: &Option<String>,
    method: String,
    path: String,
    status: u16,
    op: Option<OpKind>,
    phases: PhaseNanos,
    disposition: &'static str,
) -> Option<(usize, u64)> {
    gate.obs.trace.push(TraceEntry {
        seq: 0,
        id: id.clone().unwrap_or_else(|| "-".to_string()),
        method,
        path,
        status,
        op: op.map(OpKind::name),
        total_ns: phases.total(),
        phases,
        disposition,
    })
}

/// Which store op class a wire request maps to, for the `/metricz`
/// counters. Screened rejections and replayed responses never get here
/// — only requests that actually reach the router are ops. Debug-only
/// routes (`?live=`, `GET /v1-upload`, `/healthz`) classify as `None`:
/// they are not REST ops in the store front end either. `pub(crate)`
/// so [`super::client::HttpBackend`] counts its side of the wire with
/// the identical table — that equality is what `stress --scrape` gates.
pub(crate) fn classify_op(method: &str, path: &str, query: &str) -> Option<OpKind> {
    let trimmed = path.trim_start_matches('/');
    if trimmed.strip_prefix("v1-upload").is_some() {
        return match method {
            // Part upload and completion POST are PUT-class requests,
            // abort is DELETE-class — same as the store's accounting.
            // GET /v1-upload (the in-flight debug probe) is not an op.
            "PUT" | "POST" => Some(OpKind::PutObject),
            "DELETE" => Some(OpKind::DeleteObject),
            _ => None,
        };
    }
    let rest = trimmed.strip_prefix("v1/")?;
    match rest.split_once('/') {
        None => match method {
            "PUT" => Some(OpKind::PutObject),
            "HEAD" => Some(OpKind::HeadContainer),
            "GET" if query_param(&parse_query(query), "live").is_none() => {
                Some(OpKind::GetContainer)
            }
            _ => None,
        },
        Some(_) => match method {
            "PUT" => Some(OpKind::PutObject),
            "GET" => Some(OpKind::GetObject),
            "HEAD" => Some(OpKind::HeadObject),
            "DELETE" => Some(OpKind::DeleteObject),
            "POST" => Some(OpKind::PutObject), // ?uploads initiate
            _ => None,
        },
    }
}

/// Content type of the Prometheus text exposition `/metricz` serves.
pub(crate) const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Shared probe-route headers: scrape responses must never be cached,
/// and each probe declares its exposition format.
fn probe_response(resp: Response, content_type: &str) -> Response {
    resp.with_header("Content-Type", content_type)
        .with_header("Cache-Control", "no-store")
}

/// Probe routes answer only GET/HEAD; anything else is a `405` carrying
/// the `Allow` header RFC 9110 requires (these used to be generic 400s).
fn method_not_allowed(path: &str, method: &str) -> Response {
    Response::new(405)
        .with_header("Allow", "GET, HEAD")
        .with_header("x-error-kind", "method-not-allowed")
        .with_header("x-error-msg", pct_encode(&format!("method {method} not valid for {path}")))
}

/// The `/metricz` body: Prometheus-style text exposition of the
/// gatekeeper's rejection/replay/chaos counters, the per-op-kind
/// executed-request counters, the observability plane's latency/byte/
/// phase histograms (cumulative `_bucket{le=...}` series), and the
/// reactor sweep stats. The original plain `name value` counter lines
/// are preserved verbatim — `# TYPE` metadata and the histogram series
/// are additions, never renames. Counter reads are relaxed atomic
/// loads; histogram snapshots merge the live buckets scrape-side
/// (private-then-merge), so the probe never blocks the request path
/// and never touches the backend.
fn metricz_response(gate: &Gatekeeper, method: &str) -> Response {
    match method {
        "GET" => {}
        "HEAD" => return probe_response(Response::new(200), PROM_CONTENT_TYPE),
        m => return method_not_allowed("/metricz", m),
    }
    let ops = gate.ops.snapshot();
    let mut body = String::new();
    for (name, value) in [
        ("gateway_throttled_429s", gate.rejected_429s()),
        ("gateway_shed_503s", gate.shed_503s()),
        ("gateway_rejected_auths", gate.rejected_auths()),
        ("gateway_replayed_responses", gate.replay.replayed()),
        ("gateway_chaos_injected", gate.chaos_injected()),
    ] {
        body.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
    }
    body.push_str("# TYPE gateway_chaos_injected_kind counter\n");
    for (kind, n) in CHAOS_KINDS.iter().zip(gate.chaos_injected_by_kind()) {
        body.push_str(&format!("gateway_chaos_injected_kind{{kind=\"{kind}\"}} {n}\n"));
    }
    for (name, value) in [
        ("gateway_replay_cache_occupancy", gate.replay.occupancy() as u64),
        ("gateway_replay_cache_capacity", gate.replay.capacity() as u64),
    ] {
        body.push_str(&format!("# TYPE {name} gauge\n{name} {value}\n"));
    }
    body.push_str("# TYPE store_ops counter\n");
    for kind in OpKind::ALL {
        body.push_str(&format!(
            "store_ops{{op=\"{}\"}} {}\n",
            kind.name(),
            ops.get(kind)
        ));
    }
    for (name, value) in [
        ("store_bytes_read", ops.bytes_read),
        ("store_bytes_written", ops.bytes_written),
    ] {
        body.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
    }
    // ---- observability plane: histograms + quantile gauges ----
    let obs = &gate.obs;
    body.push_str("# TYPE gateway_serve_seconds histogram\n");
    for kind in OpKind::ALL {
        let h = obs.requests.serve_for(kind).snapshot();
        if !h.is_empty() {
            push_histogram(&mut body, "gateway_serve_seconds", Some(("op", kind.name())), &h, 1e9);
        }
    }
    // Parse-friendly per-op-class quantiles (µs): what `stress --scrape`
    // embeds next to the client-side percentiles in BENCH_10.json.
    body.push_str("# TYPE gateway_serve_latency_us gauge\n");
    for kind in OpKind::ALL {
        let h = obs.requests.serve_for(kind).snapshot();
        if h.is_empty() {
            continue;
        }
        let s = h.summary();
        for (q, v) in [
            ("p50", s.p50_us),
            ("p95", s.p95_us),
            ("p99", s.p99_us),
            ("mean", s.mean_us),
            ("max", s.max_us),
        ] {
            body.push_str(&format!(
                "gateway_serve_latency_us{{op=\"{}\",q=\"{q}\"}} {v}\n",
                kind.name()
            ));
        }
    }
    body.push_str("# TYPE gateway_phase_seconds histogram\n");
    for (i, phase) in PHASES.iter().enumerate() {
        let h = obs.requests.phase(i).snapshot();
        if !h.is_empty() {
            push_histogram(&mut body, "gateway_phase_seconds", Some(("phase", phase)), &h, 1e9);
        }
    }
    let unit = UNIT_SCALE as f64;
    body.push_str("# TYPE gateway_request_bytes histogram\n");
    for kind in OpKind::ALL {
        let h = obs.requests.request_bytes_for(kind).snapshot();
        if !h.is_empty() {
            push_histogram(&mut body, "gateway_request_bytes", Some(("op", kind.name())), &h, unit);
        }
    }
    body.push_str("# TYPE gateway_response_bytes histogram\n");
    for kind in OpKind::ALL {
        let h = obs.requests.response_bytes_for(kind).snapshot();
        if !h.is_empty() {
            push_histogram(&mut body, "gateway_response_bytes", Some(("op", kind.name())), &h, unit);
        }
    }
    // ---- reactor sweep stats (all zero under the threaded core) ----
    for (name, value) in [
        ("reactor_sweep_passes", obs.sweep.passes.load(Ordering::Relaxed)),
        ("reactor_sweep_idle_sleeps", obs.sweep.idle_sleeps.load(Ordering::Relaxed)),
        ("reactor_accepted_conns", obs.sweep.accepted.load(Ordering::Relaxed)),
        ("reactor_bytes_in", obs.sweep.bytes_in.load(Ordering::Relaxed)),
        ("reactor_bytes_out", obs.sweep.bytes_out.load(Ordering::Relaxed)),
    ] {
        body.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
    }
    for (name, hist) in [
        ("reactor_conns_polled", &obs.sweep.conns_polled),
        ("reactor_bytes_moved", &obs.sweep.bytes_moved),
        ("reactor_accept_burst", &obs.sweep.accept_burst),
    ] {
        body.push_str(&format!("# TYPE {name} histogram\n"));
        let h = hist.snapshot();
        if !h.is_empty() {
            push_histogram(&mut body, name, None, &h, unit);
        }
    }
    for (name, value) in [
        ("tracez_pushed", obs.trace.pushed()),
        ("tracez_dropped", obs.trace.dropped()),
    ] {
        body.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
    }
    probe_response(Response::new(200).with_body(body.into_bytes()), PROM_CONTENT_TYPE)
}

/// Append one Prometheus histogram: cumulative `_bucket{le=...}` series
/// trimmed to the occupied bucket range (omitted leading buckets are
/// all-zero; omitted trailing ones all equal `_count`), then the
/// `+Inf` bucket, `_sum`, and `_count`. `le_div` converts the bucket
/// bounds' nanoseconds into the exposition unit: `1e9` for seconds,
/// [`UNIT_SCALE`] for raw unit histograms (bytes, connection counts).
fn push_histogram(
    body: &mut String,
    name: &str,
    label: Option<(&str, &str)>,
    h: &Histogram,
    le_div: f64,
) {
    let with_le = |le: &str| match label {
        Some((k, v)) => format!("{{{k}=\"{v}\",le=\"{le}\"}}"),
        None => format!("{{le=\"{le}\"}}"),
    };
    let plain = match label {
        Some((k, v)) => format!("{{{k}=\"{v}\"}}"),
        None => String::new(),
    };
    let counts = h.bucket_counts();
    let range = counts
        .iter()
        .position(|&n| n > 0)
        .zip(counts.iter().rposition(|&n| n > 0));
    if let Some((first, last)) = range {
        let mut cum = 0u64;
        for (i, &n) in counts.iter().enumerate().take(last + 1).skip(first) {
            cum += n;
            let le = bucket_upper_nanos(i) as f64 / le_div;
            body.push_str(&format!("{name}_bucket{} {cum}\n", with_le(&le.to_string())));
        }
    }
    body.push_str(&format!("{name}_bucket{} {}\n", with_le("+Inf"), h.count()));
    body.push_str(&format!("{name}_sum{plain} {}\n", h.sum_nanos() as f64 / le_div));
    body.push_str(&format!("{name}_count{plain} {}\n", h.count()));
}

/// The `/tracez` body: the trace ring's retained entries (oldest
/// first) as pretty-printed JSON — trace id, method/path/status, op
/// class, disposition, and the per-phase microsecond split. Scrape
/// path only: snapshotting locks ring slots briefly, which the
/// request path never does (writers `try_lock` and drop on contention).
fn tracez_response(gate: &Gatekeeper, method: &str) -> Response {
    match method {
        "GET" => {}
        "HEAD" => return probe_response(Response::new(200), "application/json"),
        m => return method_not_allowed("/tracez", m),
    }
    let us = |n: u64| n as f64 / 1000.0;
    let entries: Vec<Json> = gate
        .obs
        .trace
        .snapshot()
        .into_iter()
        .map(|e| {
            Json::obj()
                .set("seq", e.seq)
                .set("id", e.id)
                .set("method", e.method)
                .set("path", e.path)
                .set("status", u64::from(e.status))
                .set("op", e.op.map_or(Json::Null, Json::from))
                .set("disposition", e.disposition)
                .set("total_us", us(e.total_ns))
                .set(
                    "phases_us",
                    Json::obj()
                        .set("queue", us(e.phases.queue))
                        .set("parse", us(e.phases.parse))
                        .set("screen", us(e.phases.screen))
                        .set("route", us(e.phases.route))
                        .set("serialize", us(e.phases.serialize)),
                )
        })
        .collect();
    let doc = Json::obj()
        .set("ring_slots", TRACE_RING_SLOTS)
        .set("pushed", gate.obs.trace.pushed())
        .set("dropped", gate.obs.trace.dropped())
        .set("entries", Json::Arr(entries));
    probe_response(
        Response::new(200).with_body(doc.to_pretty().into_bytes()),
        "application/json",
    )
}

/// Where the chaos plane cuts a serialized response of `len` bytes.
/// `KillResponse` cuts early (inside the status line or headers);
/// `Truncate` cuts late (inside the body, after a truthful
/// `Content-Length` promised more). Both cut strictly inside the
/// message, so the peer observes a torn response — never an empty or
/// accidentally-complete one.
pub(crate) fn chaos_cut(action: ChaosAction, len: usize) -> usize {
    match action {
        // ≤16 bytes lands mid-status-line on every real response.
        ChaosAction::KillResponse => (len / 4).max(1).min(16).min(len.saturating_sub(1)),
        ChaosAction::Truncate => len.saturating_sub((len / 8).max(1)),
        ChaosAction::None | ChaosAction::Stall => len,
    }
}

// ---- error mapping ---------------------------------------------------------

/// Machine-readable error kinds (the `x-error-kind` header values).
fn error_response(e: &BackendError) -> Response {
    let (status, kind) = match e {
        BackendError::NoSuchContainer(_) => (404, "no-such-container"),
        BackendError::NoSuchKey(_) => (404, "no-such-key"),
        BackendError::ContainerAlreadyExists(_) => (409, "container-exists"),
        BackendError::NoSuchUpload(_) => (404, "no-such-upload"),
        BackendError::InvalidRequest(_) => (400, "invalid-request"),
        BackendError::InvalidRange(_) => (416, "invalid-range"),
        BackendError::Io(_) => (500, "io"),
    };
    let resp = Response::new(status).with_header("x-error-kind", kind);
    match e {
        // The client rebuilds name-bearing errors from its own local
        // names; only free-text messages need to travel.
        BackendError::InvalidRequest(m) | BackendError::Io(m) => {
            resp.with_header("x-error-msg", pct_encode(m))
        }
        _ => resp,
    }
}

fn bad_request(msg: &str) -> Response {
    Response::new(400)
        .with_header("x-error-kind", "invalid-request")
        .with_header("x-error-msg", pct_encode(msg))
}

// ---- header rendering / parsing -------------------------------------------

fn push_meta_headers(resp: &mut Response, metadata: &Metadata) {
    for (k, v) in metadata {
        let (name, value) = meta_header(k, v);
        resp.headers.push(name, value);
    }
}

fn parse_meta_headers(req: &Request) -> Option<Metadata> {
    let mut md = Metadata::new();
    for (k, v) in req.headers.with_prefix("x-object-meta-") {
        md.insert(pct_decode(k)?, pct_decode(v)?);
    }
    Some(md)
}

fn created_at(req: &Request) -> SimInstant {
    SimInstant(
        req.headers
            .get("x-sim-created-at")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0),
    )
}

fn stat_headers(resp: &mut Response, size: u64, etag: u64, created: SimInstant, md: &Metadata) {
    resp.headers.push("ETag", format!("\"{etag:016x}\""));
    resp.headers.push("x-object-size", size.to_string());
    resp.headers.push("x-sim-created-at", created.0.to_string());
    push_meta_headers(resp, md);
}

/// Parse `Range: bytes=a-b` into `(offset, len)`. The gateway only ever
/// receives the closed form its own client sends. Checked arithmetic:
/// `bytes=0-u64::MAX` must be a clean 400, not an overflow.
fn parse_range(spec: &str) -> Option<(u64, u64)> {
    let (a, b) = spec.strip_prefix("bytes=")?.split_once('-')?;
    let start: u64 = a.parse().ok()?;
    let end: u64 = b.parse().ok()?;
    let len = end.checked_sub(start)?.checked_add(1)?;
    Some((start, len))
}

// ---- routing ---------------------------------------------------------------

/// Dispatch one request against the backend. Takes the request mutably
/// so body-consuming routes (object PUT, part upload) can move the
/// payload out instead of copying it. `pub(crate)` so the reactor core
/// routes through the identical table.
pub(crate) fn route(backend: &dyn Backend, req: &mut Request) -> Response {
    let path = std::mem::take(&mut req.path);
    let trimmed = path.trim_start_matches('/');
    if let Some(rest) = trimmed.strip_prefix("v1-upload") {
        return route_upload(backend, req, rest.trim_start_matches('/'));
    }
    if let Some(rest) = trimmed.strip_prefix("v1/") {
        return match rest.split_once('/') {
            None => route_container(backend, req, rest),
            Some((container, key)) => route_object(backend, req, container, key),
        };
    }
    bad_request(&format!("no such route: {} {path}", req.method))
}

fn route_container(backend: &dyn Backend, req: &mut Request, container_enc: &str) -> Response {
    let Some(container) = pct_decode(container_enc) else {
        return bad_request("undecodable container name");
    };
    let query = parse_query(&req.query);
    match req.method.as_str() {
        "PUT" => match backend.create_container(&container) {
            Ok(()) => Response::new(201),
            Err(e) => error_response(&e),
        },
        "HEAD" => {
            if backend.container_exists(&container) {
                Response::new(200)
            } else {
                error_response(&BackendError::NoSuchContainer(container))
            }
        }
        "GET" => match query_param(&query, "live") {
            Some("count") => {
                Response::new(200).with_body(backend.live_count(&container).to_string().into_bytes())
            }
            Some("bytes") => {
                Response::new(200).with_body(backend.live_bytes(&container).to_string().into_bytes())
            }
            Some(other) => bad_request(&format!("unknown live stat '{other}'")),
            None => {
                let prefix = query_param(&query, "prefix").unwrap_or("");
                let marker = query_param(&query, "marker");
                let limit: usize = match query_param(&query, "limit").map(str::parse) {
                    None => 1000,
                    Some(Ok(n)) => n,
                    Some(Err(_)) => return bad_request("bad limit"),
                };
                match backend.list_page(&container, prefix, marker, limit) {
                    Ok(page) => {
                        let mut body = String::new();
                        for e in &page.entries {
                            body.push_str(&format!(
                                "{} {} {:016x}\n",
                                pct_encode(&e.name),
                                e.size,
                                e.etag
                            ));
                        }
                        let mut resp = Response::new(200).with_body(body.into_bytes());
                        if let Some(next) = &page.next {
                            resp.headers.push("x-next-marker", pct_encode(next));
                        }
                        resp
                    }
                    Err(e) => error_response(&e),
                }
            }
        },
        m => bad_request(&format!("method {m} not valid for a container")),
    }
}

fn route_object(
    backend: &dyn Backend,
    req: &mut Request,
    container_enc: &str,
    key_enc: &str,
) -> Response {
    let (Some(container), Some(key)) = (pct_decode(container_enc), pct_decode(key_enc)) else {
        return bad_request("undecodable container/key");
    };
    match req.method.as_str() {
        "PUT" => {
            let Some(metadata) = parse_meta_headers(req) else {
                return bad_request("undecodable x-object-meta header");
            };
            // Move the payload out — the request is done with it.
            let obj = Object::new(std::mem::take(&mut req.body), metadata, created_at(req));
            let etag = obj.etag;
            match backend.put(&container, &key, obj) {
                Ok(replaced) => Response::new(201)
                    .with_header("ETag", format!("\"{etag:016x}\""))
                    .with_header("x-replaced", if replaced { "true" } else { "false" }),
                Err(e) => error_response(&e),
            }
        }
        "GET" => match req.headers.get("range") {
            None => match backend.get(&container, &key) {
                Ok(obj) => {
                    let mut resp = Response::new(200).with_body(obj.data.as_ref().clone());
                    stat_headers(&mut resp, obj.size(), obj.etag, obj.created_at, &obj.metadata);
                    resp
                }
                Err(e) => error_response(&e),
            },
            Some(spec) => {
                let Some((offset, len)) = parse_range(spec) else {
                    return bad_request(&format!("unparseable Range '{spec}'"));
                };
                match backend.get_range(&container, &key, offset, len) {
                    Ok((data, stat)) => {
                        let mut resp = Response::new(206);
                        if !data.is_empty() {
                            resp.headers.push(
                                "Content-Range",
                                format!(
                                    "bytes {}-{}/{}",
                                    offset,
                                    offset + data.len() as u64 - 1,
                                    stat.size
                                ),
                            );
                        }
                        stat_headers(&mut resp, stat.size, stat.etag, stat.created_at, &stat.metadata);
                        resp.with_body(data)
                    }
                    Err(BackendError::InvalidRange(_)) => {
                        // The 416: the client rebuilds the error from
                        // the standard unsatisfied-range total.
                        let size = backend.head(&container, &key).map(|s| s.size).unwrap_or(0);
                        Response::new(416)
                            .with_header("x-error-kind", "invalid-range")
                            .with_header("Content-Range", format!("bytes */{size}"))
                    }
                    Err(e) => error_response(&e),
                }
            }
        },
        "HEAD" => match backend.head(&container, &key) {
            Ok(stat) => {
                let mut resp = Response::new(200);
                stat_headers(&mut resp, stat.size, stat.etag, stat.created_at, &stat.metadata);
                resp
            }
            Err(e) => error_response(&e),
        },
        "DELETE" => match backend.delete(&container, &key) {
            Ok(stat) => {
                let mut resp = Response::new(204);
                stat_headers(&mut resp, stat.size, stat.etag, stat.created_at, &stat.metadata);
                resp
            }
            Err(e) => error_response(&e),
        },
        "POST" if query_param(&parse_query(&req.query), "uploads").is_some() => {
            let Some(metadata) = parse_meta_headers(req) else {
                return bad_request("undecodable x-object-meta header");
            };
            match backend.initiate_multipart(&container, &key, metadata) {
                Ok(id) => Response::new(200).with_header("x-upload-id", id.to_string()),
                Err(e) => error_response(&e),
            }
        }
        m => bad_request(&format!(
            "method {m}{} not valid for an object",
            if m == "POST" { " (without ?uploads)" } else { "" }
        )),
    }
}

/// `/v1-upload[/{id}[/{part}]]` — the multipart lifecycle.
fn route_upload(backend: &dyn Backend, req: &mut Request, rest: &str) -> Response {
    if rest.is_empty() {
        return match req.method.as_str() {
            "GET" => Response::new(200)
                .with_body(backend.multipart_in_flight().to_string().into_bytes()),
            m => bad_request(&format!("method {m} not valid for the upload root")),
        };
    }
    let (id_s, part_s) = match rest.split_once('/') {
        Some((i, p)) => (i, Some(p)),
        None => (rest, None),
    };
    let Ok(id) = id_s.parse::<u64>() else {
        return bad_request(&format!("bad upload id '{id_s}'"));
    };
    match (req.method.as_str(), part_s) {
        ("PUT", Some(part_s)) => {
            let Ok(part) = part_s.parse::<u32>() else {
                return bad_request(&format!("bad part number '{part_s}'"));
            };
            match backend.upload_part(id, part, std::mem::take(&mut req.body)) {
                Ok(()) => Response::new(201),
                Err(e) => error_response(&e),
            }
        }
        ("POST", None) => {
            let query = parse_query(&req.query);
            let min_part_size: u64 = match query_param(&query, "min-part-size").map(str::parse) {
                None => 0,
                Some(Ok(n)) => n,
                Some(Err(_)) => return bad_request("bad min-part-size"),
            };
            match backend.complete_multipart(id, min_part_size) {
                Ok(asm) => {
                    let mut resp = Response::new(200)
                        .with_header("x-container", pct_encode(&asm.container))
                        .with_header("x-key", pct_encode(&asm.key));
                    push_meta_headers(&mut resp, &asm.metadata);
                    resp.with_body(asm.data)
                }
                Err(e) => error_response(&e),
            }
        }
        ("DELETE", None) => match backend.abort_multipart(id) {
            Ok(()) => Response::new(204),
            Err(e) => error_response(&e),
        },
        (m, _) => bad_request(&format!("method {m} not valid for an upload")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::HttpBackend;
    use crate::objectstore::backend::{clamp_range, ShardedMemBackend};

    fn gateway() -> (GatewayHandle, HttpBackend) {
        let inner = Arc::new(ShardedMemBackend::new(4));
        let server = GatewayServer::bind("127.0.0.1:0", inner).expect("bind ephemeral");
        let handle = server.spawn();
        let client =
            HttpBackend::connect(&handle.addr().to_string(), None).expect("connect");
        (handle, client)
    }

    fn obj(data: &[u8], t: u64) -> Object {
        Object::new(data.to_vec(), Metadata::new(), SimInstant(t))
    }

    #[test]
    fn full_protocol_over_a_real_socket() {
        let (_handle, b) = gateway();
        // Containers.
        assert!(!b.container_exists("res"));
        b.create_container("res").unwrap();
        assert!(b.container_exists("res"));
        assert!(matches!(
            b.create_container("res"),
            Err(BackendError::ContainerAlreadyExists(c)) if c == "res"
        ));
        // Objects with metadata + created_at + ETag round-trip.
        let mut md = Metadata::new();
        md.insert("X-Stocator-Origin".into(), "stocator 1.0/a+b".into());
        let stored = Object::new(b"payload".to_vec(), md, SimInstant(7));
        let etag = stored.etag;
        assert!(!b.put("res", "d/part-0", stored).unwrap());
        assert!(b.put("res", "d/part-0", obj(b"payload", 7)).unwrap(), "replace");
        let got = b.get("res", "d/part-0").unwrap();
        assert_eq!(&**got.data, b"payload");
        assert_eq!(got.etag, etag);
        assert_eq!(got.created_at, SimInstant(7));
        // Ranged GET carries the FULL stat; 416 matches clamp_range.
        let (bytes, stat) = b.get_range("res", "d/part-0", 2, 3).unwrap();
        assert_eq!(bytes, b"ylo");
        assert_eq!(stat.size, 7);
        let err = b.get_range("res", "d/part-0", 8, 1).unwrap_err();
        assert_eq!(err, clamp_range("res", "d/part-0", 8, 1, 7).unwrap_err());
        // Listing + pagination token.
        for i in 0..5 {
            b.put("res", &format!("p/{i}"), obj(b"x", 0)).unwrap();
        }
        let page = b.list_page("res", "p/", None, 3).unwrap();
        assert_eq!(page.entries.len(), 3);
        assert_eq!(page.next.as_deref(), Some("p/2"));
        let rest = b.list_page("res", "p/", page.next.as_deref(), 10).unwrap();
        assert_eq!(rest.entries.len(), 2);
        assert!(rest.next.is_none());
        // Multipart lifecycle.
        let id = b.initiate_multipart("res", "big", Metadata::new()).unwrap();
        b.upload_part(id, 2, b"world".to_vec()).unwrap();
        b.upload_part(id, 1, b"hello ".to_vec()).unwrap();
        assert_eq!(b.multipart_in_flight(), 1);
        let asm = b.complete_multipart(id, 0).unwrap();
        assert_eq!(asm.container, "res");
        assert_eq!(asm.key, "big");
        assert_eq!(asm.data, b"hello world");
        assert_eq!(b.multipart_in_flight(), 0);
        assert!(matches!(
            b.abort_multipart(id),
            Err(BackendError::NoSuchUpload(got)) if got == id
        ));
        // Delete returns the final stat; live stats flow through.
        assert!(b.live_count("res") >= 6);
        let stat = b.delete("res", "d/part-0").unwrap();
        assert_eq!(stat.size, 7);
        assert!(matches!(
            b.get("res", "d/part-0"),
            Err(BackendError::NoSuchKey(k)) if k == "res/d/part-0"
        ));
    }

    #[test]
    fn namespaced_clients_get_disjoint_worlds() {
        let inner = Arc::new(ShardedMemBackend::new(2));
        let server = GatewayServer::bind("127.0.0.1:0", inner.clone()).unwrap();
        let handle = server.spawn();
        let addr = handle.addr().to_string();
        let a = HttpBackend::connect(&addr, Some("w1".into())).unwrap();
        let c = HttpBackend::connect(&addr, Some("w2".into())).unwrap();
        // Both create "res" — no collision, because the wire names differ.
        a.create_container("res").unwrap();
        c.create_container("res").unwrap();
        a.put("res", "k", obj(b"from-a", 0)).unwrap();
        assert!(matches!(c.get("res", "k"), Err(BackendError::NoSuchKey(k)) if k == "res/k"));
        // The inner backend really holds both namespaced containers.
        assert!(inner.container_exists("w1.res"));
        assert!(inner.container_exists("w2.res"));
        // Multipart targets un-namespace on the way back.
        let id = a.initiate_multipart("res", "mp", Metadata::new()).unwrap();
        a.upload_part(id, 1, b"x".to_vec()).unwrap();
        let asm = a.complete_multipart(id, 0).unwrap();
        assert_eq!(asm.container, "res");
    }

    #[test]
    fn healthz_answers_without_touching_the_backend() {
        use std::io::{Read, Write};
        let (handle, _b) = gateway();
        for req in ["GET /healthz HTTP/1.1\r\n\r\n", "HEAD /healthz HTTP/1.1\r\n\r\n"] {
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(req.as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut resp = String::new();
            let _ = s.read_to_string(&mut resp);
            assert!(resp.starts_with("HTTP/1.1 200"), "{req} got: {resp}");
        }
        // Probe GETs carry no-store + a content type.
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = s.read_to_string(&mut resp);
        assert!(resp.contains("Cache-Control: no-store"), "got: {resp}");
        assert!(resp.contains("Content-Type: text/plain"), "got: {resp}");
        // Other methods are 405s with the required Allow header.
        for probe in ["/healthz", "/metricz", "/tracez"] {
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(format!("DELETE {probe} HTTP/1.1\r\n\r\n").as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut resp = String::new();
            let _ = s.read_to_string(&mut resp);
            assert!(resp.starts_with("HTTP/1.1 405"), "{probe} got: {resp}");
            assert!(resp.contains("Allow: GET, HEAD"), "{probe} got: {resp}");
        }
    }

    #[test]
    fn metricz_reports_gatekeeper_and_op_counters_on_both_cores() {
        use std::io::{Read, Write};
        let scrape = |addr: SocketAddr| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /metricz HTTP/1.1\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut resp = String::new();
            let _ = s.read_to_string(&mut resp);
            assert!(resp.starts_with("HTTP/1.1 200"), "got: {resp}");
            resp
        };
        for mode in [GatewayMode::Threaded, GatewayMode::Reactor] {
            let inner = Arc::new(ShardedMemBackend::new(4));
            let server = GatewayServer::bind_with(
                "127.0.0.1:0",
                inner,
                GatewayConfig {
                    mode,
                    ..GatewayConfig::default()
                },
            )
            .expect("bind ephemeral");
            let handle = server.spawn();
            let b = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect");
            // A fresh gateway scrapes all-zero...
            let before = scrape(handle.addr());
            assert!(before.contains("gateway_throttled_429s 0"), "{mode:?}: {before}");
            assert!(
                before.contains("store_ops{op=\"PUT Object\"} 0"),
                "{mode:?}: {before}"
            );
            // ...and executed requests move exactly the right counters.
            b.create_container("res").unwrap();
            b.put("res", "k", obj(b"abcde", 0)).unwrap();
            b.get("res", "k").unwrap();
            b.head("res", "k").unwrap();
            b.list_page("res", "", None, 10).unwrap();
            b.delete("res", "k").unwrap();
            let after = scrape(handle.addr());
            // create_container + object PUT = 2 PUT-class requests.
            assert!(
                after.contains("store_ops{op=\"PUT Object\"} 2"),
                "{mode:?}: {after}"
            );
            assert!(after.contains("store_ops{op=\"GET Object\"} 1"), "{mode:?}: {after}");
            assert!(after.contains("store_ops{op=\"HEAD Object\"} 1"), "{mode:?}: {after}");
            assert!(
                after.contains("store_ops{op=\"DELETE Object\"} 1"),
                "{mode:?}: {after}"
            );
            assert!(
                after.contains("store_ops{op=\"GET Container\"} 1"),
                "{mode:?}: {after}"
            );
            assert!(after.contains("store_bytes_written 5"), "{mode:?}: {after}");
            assert!(after.contains("store_bytes_read 5"), "{mode:?}: {after}");
            // Prometheus exposition: typed families, versioned content
            // type, no-store, and the new gauge/counter families.
            assert!(after.contains("# TYPE store_ops counter"), "{mode:?}: {after}");
            assert!(
                after.contains("Content-Type: text/plain; version=0.0.4"),
                "{mode:?}: {after}"
            );
            assert!(after.contains("Cache-Control: no-store"), "{mode:?}: {after}");
            assert!(after.contains("gateway_replay_cache_capacity 256"), "{mode:?}: {after}");
            assert!(
                after.contains("gateway_chaos_injected_kind{kind=\"reset\"} 0"),
                "{mode:?}: {after}"
            );
            // Serve histograms recorded the executed ops: the PUT class
            // saw exactly 2 (exposed as the +Inf cumulative bucket).
            assert!(
                after.contains("gateway_serve_seconds_bucket{op=\"PUT Object\",le=\"+Inf\"} 2"),
                "{mode:?}: {after}"
            );
            assert!(
                after.contains("gateway_serve_latency_us{op=\"GET Object\",q=\"p50\"}"),
                "{mode:?}: {after}"
            );
            // The scrape itself is never an op (two scrapes so far, no
            // drift) and /metricz answers HEAD like /healthz.
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(b"HEAD /metricz HTTP/1.1\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut resp = String::new();
            let _ = s.read_to_string(&mut resp);
            assert!(resp.starts_with("HTTP/1.1 200"), "{mode:?}: {resp}");
        }
    }

    #[test]
    fn metricz_histogram_buckets_are_cumulative_and_monotone() {
        use std::io::{Read, Write};
        let (handle, b) = gateway();
        b.create_container("res").unwrap();
        // A spread of payload sizes so several buckets populate.
        for (i, size) in [(0usize, 10usize), (1, 1000), (2, 100_000), (3, 16)] {
            b.put("res", &format!("k{i}"), obj(&vec![7u8; size], 0)).unwrap();
        }
        for i in 0..4 {
            b.get("res", &format!("k{i}")).unwrap();
        }
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /metricz HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut scrape = String::new();
        let _ = s.read_to_string(&mut scrape);
        // Every exposed histogram family: cumulative bucket series are
        // non-decreasing in `le` order (the emission order), and the
        // +Inf bucket equals the family's _count.
        let mut checked = 0;
        for family in [
            "gateway_serve_seconds_bucket{op=\"PUT Object\",",
            "gateway_serve_seconds_bucket{op=\"GET Object\",",
            "gateway_response_bytes_bucket{op=\"GET Object\",",
            "gateway_request_bytes_bucket{op=\"PUT Object\",",
            "gateway_phase_seconds_bucket{phase=\"route\",",
        ] {
            let counts: Vec<u64> = scrape
                .lines()
                .filter(|l| l.starts_with(family))
                .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
                .collect();
            assert!(counts.len() >= 2, "{family} series missing: {scrape}");
            assert!(
                counts.windows(2).all(|w| w[0] <= w[1]),
                "{family} not monotone: {counts:?}"
            );
            checked += 1;
            // +Inf (the last bucket emitted) == _count for the family.
            let count_line_prefix = family.replace("_bucket", "_count");
            let count_line_prefix = count_line_prefix.trim_end_matches(',').to_string() + "}";
            let count: u64 = scrape
                .lines()
                .find(|l| l.starts_with(&count_line_prefix))
                .and_then(|l| l.rsplit(' ').next())
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(|| panic!("no _count line for {family}"));
            assert_eq!(*counts.last().unwrap(), count, "{family}");
        }
        assert_eq!(checked, 5);
        // Byte histograms resolved the size spread: 10B and 100KB GETs
        // must not share a bucket (distinct le series entries).
        let resp_buckets: Vec<&str> = scrape
            .lines()
            .filter(|l| l.starts_with("gateway_response_bytes_bucket{op=\"GET Object\","))
            .collect();
        assert!(resp_buckets.len() >= 3, "{resp_buckets:?}");
    }

    #[test]
    fn tracez_rings_the_last_requests_with_phase_splits() {
        use std::io::{Read, Write};
        let scrape_tracez = |addr: SocketAddr| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /tracez HTTP/1.1\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut resp = String::new();
            let _ = s.read_to_string(&mut resp);
            resp
        };
        for mode in [GatewayMode::Threaded, GatewayMode::Reactor] {
            let inner = Arc::new(ShardedMemBackend::new(4));
            let server = GatewayServer::bind_with(
                "127.0.0.1:0",
                inner,
                GatewayConfig { mode, ..GatewayConfig::default() },
            )
            .expect("bind ephemeral");
            let handle = server.spawn();
            let b = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect");
            b.create_container("res").unwrap();
            b.put("res", "k", obj(b"hello", 3)).unwrap();
            b.get("res", "k").unwrap();
            let resp = scrape_tracez(handle.addr());
            assert!(resp.starts_with("HTTP/1.1 200"), "{mode:?}: {resp}");
            assert!(resp.contains("Content-Type: application/json"), "{mode:?}: {resp}");
            assert!(resp.contains("Cache-Control: no-store"), "{mode:?}: {resp}");
            // All three executed requests are traced, with op classes,
            // ok dispositions, and phase splits.
            assert!(resp.contains("\"op\": \"PUT Object\""), "{mode:?}: {resp}");
            assert!(resp.contains("\"op\": \"GET Object\""), "{mode:?}: {resp}");
            assert!(resp.contains("\"disposition\": \"ok\""), "{mode:?}: {resp}");
            assert!(resp.contains("\"phases_us\""), "{mode:?}: {resp}");
            assert!(resp.contains("\"pushed\": 3"), "{mode:?}: {resp}");
            // The /tracez scrape itself (and /metricz, /healthz) is
            // never traced: scrape again, pushed is unchanged.
            let again = scrape_tracez(handle.addr());
            assert!(again.contains("\"pushed\": 3"), "{mode:?}: {again}");
        }
        // Observability off: requests still serve, the ring stays empty.
        let inner = Arc::new(ShardedMemBackend::new(1));
        let server = GatewayServer::bind_with(
            "127.0.0.1:0",
            inner,
            GatewayConfig { observability: false, ..GatewayConfig::default() },
        )
        .expect("bind ephemeral");
        let handle = server.spawn();
        let b = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect");
        b.create_container("res").unwrap();
        let resp = scrape_tracez(handle.addr());
        assert!(resp.contains("\"pushed\": 0"), "got: {resp}");
        assert!(resp.contains("\"entries\": []"), "got: {resp}");
    }

    #[test]
    fn traces_label_rejections_and_chaos_dispositions() {
        use std::io::{Read, Write};
        use crate::gateway::config::ChaosConfig;
        // Auth-armed gateway: a rejected request is traced as such.
        let inner = Arc::new(ShardedMemBackend::new(1));
        let server = GatewayServer::bind_with(
            "127.0.0.1:0",
            inner,
            GatewayConfig { auth_token: Some("tok".into()), ..GatewayConfig::default() },
        )
        .expect("bind");
        let handle = server.spawn();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /v1/res HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = s.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 401"), "got: {resp}");
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /tracez HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut trace = String::new();
        let _ = s.read_to_string(&mut trace);
        assert!(trace.contains("\"disposition\": \"rejected-auth\""), "got: {trace}");
        assert!(trace.contains("\"status\": 401"), "got: {trace}");
        drop(handle);
        // Chaos-armed gateway: killed responses get their trace entry
        // patched post-hoc at the connection layer. Half the responses
        // (scrapes included) are torn, so retry the scrape until one
        // survives intact.
        let inner = Arc::new(ShardedMemBackend::new(1));
        let server = GatewayServer::bind_with(
            "127.0.0.1:0",
            inner,
            GatewayConfig {
                chaos: ChaosConfig { kill_response: 0.5, ..ChaosConfig::default() },
                ..GatewayConfig::default()
            },
        )
        .expect("bind");
        let handle = server.spawn();
        for _ in 0..8 {
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(b"PUT /v1/res HTTP/1.1\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut torn_or_ok = String::new();
            let _ = s.read_to_string(&mut torn_or_ok); // possibly torn; ignore
        }
        let mut patched = false;
        for _ in 0..64 {
            let mut s = TcpStream::connect(handle.addr()).unwrap();
            s.write_all(b"GET /tracez HTTP/1.1\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut trace = String::new();
            let _ = s.read_to_string(&mut trace);
            // The scrape itself may be chaos-torn; a torn response
            // simply won't contain the full needle and we go again.
            if trace.contains("\"disposition\": \"chaos-kill-response\"") {
                patched = true;
                break;
            }
        }
        assert!(patched, "no chaos-kill-response disposition ever appeared in /tracez");
    }

    #[test]
    fn metricz_is_exempt_from_auth_like_healthz() {
        use std::io::{Read, Write};
        let inner = Arc::new(ShardedMemBackend::new(1));
        let server = GatewayServer::bind_with(
            "127.0.0.1:0",
            inner,
            GatewayConfig {
                auth_token: Some("s3cr3t".to_string()),
                ..GatewayConfig::default()
            },
        )
        .expect("bind ephemeral");
        let handle = server.spawn();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /metricz HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = s.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 200"), "got: {resp}");
        // The rejection counters it reports are live: one unauthorized
        // request, then re-scrape.
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /v1/res HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut rej = String::new();
        let _ = s.read_to_string(&mut rej);
        assert!(rej.starts_with("HTTP/1.1 401"), "got: {rej}");
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        s.write_all(b"GET /metricz HTTP/1.1\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = s.read_to_string(&mut resp);
        assert!(resp.contains("gateway_rejected_auths 1"), "got: {resp}");
        // Screened requests are not ops.
        assert!(resp.contains("store_ops{op=\"GET Container\"} 0"), "got: {resp}");
    }

    #[test]
    fn server_survives_malformed_requests() {
        use std::io::{Read, Write};
        let (handle, b) = gateway();
        b.create_container("res").unwrap();
        // A raw garbage connection gets a 400 and a close — and the
        // server keeps serving real clients afterwards.
        let mut garbage = TcpStream::connect(handle.addr()).unwrap();
        garbage.write_all(b"NOT HTTP AT ALL\r\n\r\n").unwrap();
        garbage.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = garbage.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        b.put("res", "k", obj(b"still alive", 1)).unwrap();
        assert_eq!(&**b.get("res", "k").unwrap().data, b"still alive");
        // Unknown routes are clean 400s, not hangs.
        let mut w = TcpStream::connect(handle.addr()).unwrap();
        w.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
        w.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = w.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // A Range whose closed form overflows u64 is a clean 400 too
        // (checked arithmetic in parse_range, not a panic).
        let mut o = TcpStream::connect(handle.addr()).unwrap();
        o.write_all(b"GET /v1/res/k HTTP/1.1\r\nRange: bytes=0-18446744073709551615\r\n\r\n")
            .unwrap();
        o.shutdown(std::net::Shutdown::Write).unwrap();
        let mut resp = String::new();
        let _ = o.read_to_string(&mut resp);
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
    }
}
