//! Hand-rolled HTTP/1.1 message framing (no external dependencies).
//!
//! Only what the gateway protocol needs: request/status lines, headers,
//! `Content-Length`-framed bodies, persistent connections (HTTP/1.1
//! default). No chunked transfer encoding — both ends of this protocol
//! always know their body sizes up front — and no TLS. Limits on line
//! length, header count and body size keep a hostile peer from ballooning
//! memory.

use std::io::{self, BufRead, Read, Write};

/// Longest accepted request/status/header line, in bytes.
const MAX_LINE: usize = 64 * 1024;
/// Most headers accepted per message.
const MAX_HEADERS: usize = 256;
/// Largest accepted body (1 GiB — far above any simulated object).
const MAX_BODY: u64 = 1 << 30;

/// An ordered header list. Names are matched case-insensitively (HTTP
/// semantics) but stored verbatim, so `x-object-meta-*` suffixes keep
/// their exact spelling.
#[derive(Debug, Clone, Default)]
pub struct Headers(Vec<(String, String)>);

impl Headers {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: impl Into<String>, value: impl Into<String>) {
        self.0.push((name.into(), value.into()));
    }

    /// First value whose name matches case-insensitively.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.0
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// All `(name-suffix, value)` pairs whose name starts with `prefix`
    /// (prefix matched case-insensitively, suffix returned verbatim).
    pub fn with_prefix<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a str)> + 'a {
        self.0.iter().filter_map(move |(n, v)| {
            // get() (not slicing) so a multi-byte name shorter than the
            // prefix, or one split mid-codepoint, is a miss, not a panic.
            match n.get(..prefix.len()) {
                Some(head) if head.eq_ignore_ascii_case(prefix) => {
                    Some((&n[prefix.len()..], v.as_str()))
                }
                _ => None,
            }
        })
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.0.iter().map(|(n, v)| (n.as_str(), v.as_str()))
    }
}

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path component of the target, still percent-encoded.
    pub path: String,
    /// Raw query string (no `?`), empty when absent.
    pub query: String,
    pub headers: Headers,
    pub body: Vec<u8>,
}

/// A parsed (or to-be-written) HTTP response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Headers,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Self {
        Self {
            status,
            headers: Headers::new(),
            body: Vec::new(),
        }
    }

    pub fn with_body(mut self, body: Vec<u8>) -> Self {
        self.body = body;
        self
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Self {
        self.headers.push(name, value.into());
        self
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        204 => "No Content",
        206 => "Partial Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        416 => "Range Not Satisfiable",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Read one CRLF-terminated line (LF tolerated), without the terminator.
/// `Ok(None)` = clean EOF before any byte.
fn read_line(r: &mut impl BufRead) -> io::Result<Option<String>> {
    let mut buf = Vec::new();
    let mut limited = r.take(MAX_LINE as u64 + 1);
    let n = limited.read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') {
        return Err(bad("line too long or truncated"));
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| bad("non-UTF-8 header line"))
}

/// Read headers up to the blank line.
fn read_headers(r: &mut impl BufRead) -> io::Result<Headers> {
    let mut headers = Headers::new();
    for _ in 0..MAX_HEADERS {
        let line = read_line(r)?.ok_or_else(|| bad("EOF inside headers"))?;
        if line.is_empty() {
            return Ok(headers);
        }
        let (name, value) = parse_header_line(&line)?;
        headers.push(name, value);
    }
    Err(bad("too many headers"))
}

fn read_body(r: &mut impl BufRead, headers: &Headers) -> io::Result<Vec<u8>> {
    let len = declared_len(headers)?;
    // Grow with the data actually received (Take bounds the read), so a
    // peer declaring a huge Content-Length and sending nothing cannot
    // make us preallocate the declared size.
    let mut body = Vec::with_capacity(len.min(64 * 1024) as usize);
    let got = r.take(len).read_to_end(&mut body)?;
    if (got as u64) < len {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "truncated body",
        ));
    }
    Ok(body)
}

/// Split a request line into `(method, path, query)`; HTTP/1.x only.
fn parse_request_line(line: &str) -> io::Result<(String, String, String)> {
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return Err(bad(format!("malformed request line '{line}'"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad(format!("unsupported version '{version}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    Ok((method.to_string(), path, query))
}

fn parse_header_line(line: &str) -> io::Result<(&str, &str)> {
    let (name, value) = line
        .split_once(':')
        .ok_or_else(|| bad(format!("malformed header line '{line}'")))?;
    Ok((name.trim(), value.trim()))
}

fn declared_len(headers: &Headers) -> io::Result<u64> {
    let len: u64 = match headers.get("content-length") {
        None => 0,
        Some(v) => v.parse().map_err(|_| bad("bad Content-Length"))?,
    };
    if len > MAX_BODY {
        return Err(bad("body too large"));
    }
    Ok(len)
}

/// Read one request. `Ok(None)` = the peer closed a keep-alive
/// connection cleanly between requests.
pub fn read_request(r: &mut impl BufRead) -> io::Result<Option<Request>> {
    let Some(line) = read_line(r)? else {
        return Ok(None);
    };
    let (method, path, query) = parse_request_line(&line)?;
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Some(Request {
        method,
        path,
        query,
        headers,
        body,
    }))
}

/// Incremental request parsing for the non-blocking reactor core: try
/// to parse ONE complete request from the front of `buf`.
///
/// * `Ok(Some((req, consumed)))` — a full request occupied
///   `buf[..consumed]`; the caller drains those bytes (any remainder is
///   the start of the next pipelined request).
/// * `Ok(None)` — the prefix is a valid-so-far but incomplete request;
///   read more bytes and try again.
/// * `Err(_)` — the prefix can never become a valid request. The same
///   limits as the blocking parser apply *while scanning*, so a
///   slow-loris peer dribbling an endless header line is rejected as
///   soon as it crosses `MAX_LINE`, not buffered forever.
pub fn try_parse_request(buf: &[u8]) -> io::Result<Option<(Request, usize)>> {
    let mut lines: Vec<&str> = Vec::new();
    let mut pos = 0usize;
    let head_end = loop {
        let rest = &buf[pos..];
        let Some(nl) = rest.iter().position(|&b| b == b'\n') else {
            // No terminator yet: incomplete — unless the partial line
            // or header count already exceeds what we would ever accept.
            if rest.len() > MAX_LINE {
                return Err(bad("line too long"));
            }
            if lines.len() > MAX_HEADERS {
                return Err(bad("too many headers"));
            }
            return Ok(None);
        };
        if nl > MAX_LINE {
            return Err(bad("line too long"));
        }
        let mut line = &rest[..nl];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        let line = std::str::from_utf8(line).map_err(|_| bad("non-UTF-8 header line"))?;
        pos += nl + 1;
        if line.is_empty() {
            break pos;
        }
        if lines.len() > MAX_HEADERS {
            return Err(bad("too many headers"));
        }
        lines.push(line);
    };
    let Some((request_line, header_lines)) = lines.split_first() else {
        return Err(bad("malformed request line ''"));
    };
    let (method, path, query) = parse_request_line(request_line)?;
    let mut headers = Headers::new();
    for line in header_lines {
        let (name, value) = parse_header_line(line)?;
        headers.push(name, value);
    }
    let len = declared_len(&headers)? as usize;
    let total = head_end
        .checked_add(len)
        .ok_or_else(|| bad("body too large"))?;
    if buf.len() < total {
        return Ok(None);
    }
    let body = buf[head_end..total].to_vec();
    Ok(Some((
        Request {
            method,
            path,
            query,
            headers,
            body,
        },
        total,
    )))
}

/// Write one request with an exact `Content-Length` (always present, so
/// the peer frames uniformly).
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    target: &str,
    headers: &Headers,
    body: &[u8],
) -> io::Result<()> {
    let mut out = format!("{method} {target} HTTP/1.1\r\n");
    for (n, v) in headers.iter() {
        out.push_str(&format!("{n}: {v}\r\n"));
    }
    out.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    w.write_all(out.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Error message marking EOF before any response byte arrived: the
/// peer closed a keep-alive connection between requests, so the request
/// was provably not executed and a client may safely re-send it on a
/// fresh connection. Any later failure gives no such guarantee *on its
/// own* — recovering those takes the [`REQUEST_ID`] replay protocol.
pub const STALE_CONNECTION: &str = "stale keep-alive connection (EOF before status line)";

/// Idempotency header: the client stamps every mutating request
/// (`PUT`/`POST`/`DELETE`) with a unique id and reuses that id across
/// wire re-sends of the same operation, so the gateway's replay cache
/// (`gateway::config::ReplayCache`) can answer a blind re-send with
/// the original response instead of re-executing it.
pub const REQUEST_ID: &str = "x-request-id";

/// Marker header the gateway adds to a response served from the replay
/// cache — never present on a first execution. The client counts these
/// (`HttpBackend::replayed_responses`) as proof a mid-response failure
/// was recovered without re-execution.
pub const REQUEST_REPLAYED: &str = "x-request-replayed";

/// Serialize a response to its exact wire bytes. Both server cores
/// write (and the replay cache stores) this byte-for-byte form, which
/// is also what the chaos plane cuts prefixes of.
pub fn serialize_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(resp.body.len() + 128);
    write_response(&mut out, resp).expect("writing to a Vec cannot fail");
    out
}

/// Read one response. Responses always carry an exact `Content-Length`
/// (this protocol never sends bodiless-by-method responses the client
/// would have to special-case).
pub fn read_response(r: &mut impl BufRead) -> io::Result<Response> {
    let line = read_line(r)?
        .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, STALE_CONNECTION))?;
    let mut parts = line.splitn(3, ' ');
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => {
            code.parse().map_err(|_| bad("bad status code"))?
        }
        _ => return Err(bad(format!("malformed status line '{line}'"))),
    };
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Response {
        status,
        headers,
        body,
    })
}

/// Write one response with an exact `Content-Length`.
pub fn write_response(w: &mut impl Write, resp: &Response) -> io::Result<()> {
    let mut out = format!("HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    for (n, v) in resp.headers.iter() {
        out.push_str(&format!("{n}: {v}\r\n"));
    }
    out.push_str(&format!("Content-Length: {}\r\n\r\n", resp.body.len()));
    w.write_all(out.as_bytes())?;
    w.write_all(&resp.body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn request_roundtrip() {
        let mut headers = Headers::new();
        headers.push("x-sim-created-at", "7");
        headers.push("X-Object-Meta-Origin", "stocator%201.0");
        let mut wire = Vec::new();
        write_request(&mut wire, "PUT", "/v1/res/d%2Fpart-0", &headers, b"payload").unwrap();
        let mut r = BufReader::new(&wire[..]);
        let req = read_request(&mut r).unwrap().expect("one request");
        assert_eq!(req.method, "PUT");
        assert_eq!(req.path, "/v1/res/d%2Fpart-0");
        assert_eq!(req.query, "");
        assert_eq!(req.body, b"payload");
        assert_eq!(req.headers.get("X-SIM-CREATED-AT"), Some("7"));
        let metas: Vec<_> = req.headers.with_prefix("x-object-meta-").collect();
        assert_eq!(metas, vec![("Origin", "stocator%201.0")]);
        // The stream is exhausted: next read is a clean keep-alive EOF.
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn request_with_query_splits_target() {
        let mut wire = Vec::new();
        write_request(
            &mut wire,
            "GET",
            "/v1/res?prefix=d%2F&limit=10",
            &Headers::new(),
            b"",
        )
        .unwrap();
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert_eq!(req.path, "/v1/res");
        assert_eq!(req.query, "prefix=d%2F&limit=10");
        assert!(req.body.is_empty());
    }

    #[test]
    fn response_roundtrip_binary_body() {
        let body: Vec<u8> = (0u8..=255).collect();
        let resp = Response::new(206)
            .with_header("ETag", "\"00000000deadbeef\"")
            .with_header("Content-Range", "bytes 0-255/1000")
            .with_body(body.clone());
        let mut wire = Vec::new();
        write_response(&mut wire, &resp).unwrap();
        let got = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(got.status, 206);
        assert_eq!(got.headers.get("etag"), Some("\"00000000deadbeef\""));
        assert_eq!(got.body, body);
    }

    #[test]
    fn incremental_parser_matches_blocking_parser() {
        let mut headers = Headers::new();
        headers.push("x-object-meta-k", "v");
        let mut wire = Vec::new();
        write_request(&mut wire, "PUT", "/v1/res/k?a=1", &headers, b"body!").unwrap();
        // Every strict prefix is "incomplete", never an error.
        for cut in 0..wire.len() {
            assert!(
                try_parse_request(&wire[..cut]).expect("prefix must not be malformed").is_none(),
                "prefix of {cut} bytes parsed as complete"
            );
        }
        let (req, consumed) = try_parse_request(&wire).unwrap().expect("complete request");
        assert_eq!(consumed, wire.len());
        assert_eq!(req.method, "PUT");
        assert_eq!(req.path, "/v1/res/k");
        assert_eq!(req.query, "a=1");
        assert_eq!(req.headers.get("X-Object-Meta-K"), Some("v"));
        assert_eq!(req.body, b"body!");
    }

    #[test]
    fn incremental_parser_frames_pipelined_requests() {
        let mut wire = Vec::new();
        write_request(&mut wire, "PUT", "/v1/c/a", &Headers::new(), b"xy").unwrap();
        let first_len = wire.len();
        write_request(&mut wire, "GET", "/v1/c/a", &Headers::new(), b"").unwrap();
        let (first, consumed) = try_parse_request(&wire).unwrap().expect("first request");
        assert_eq!(consumed, first_len);
        assert_eq!(first.method, "PUT");
        let (second, rest) = try_parse_request(&wire[consumed..]).unwrap().expect("second");
        assert_eq!(second.method, "GET");
        assert_eq!(consumed + rest, wire.len());
    }

    #[test]
    fn incremental_parser_rejects_hostile_prefixes() {
        assert!(try_parse_request(b"NOT-HTTP\r\n\r\n").is_err());
        assert!(try_parse_request(b"GET /x HTTP/1.1\r\nbad header\r\n\r\n").is_err());
        assert!(try_parse_request(b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n").is_err());
        assert!(try_parse_request(b"\r\n").is_err(), "blank request line is malformed");
        // A request-line with no terminator longer than MAX_LINE is
        // rejected mid-stream — a slow loris cannot balloon the buffer.
        let huge = vec![b'a'; MAX_LINE + 2];
        assert!(try_parse_request(&huge).is_err());
        // Truncated body stays incomplete, not an error.
        assert!(try_parse_request(b"GET /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab")
            .unwrap()
            .is_none());
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        let mut r = BufReader::new(&b"NOT-HTTP\r\n\r\n"[..]);
        assert!(read_request(&mut r).is_err());
        let mut r = BufReader::new(&b"GET /x HTTP/1.1\r\nbad header\r\n\r\n"[..]);
        assert!(read_request(&mut r).is_err());
        let mut r = BufReader::new(&b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n"[..]);
        assert!(read_request(&mut r).is_err());
        // Truncated body.
        let mut r = BufReader::new(&b"GET /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab"[..]);
        assert!(read_request(&mut r).is_err());
    }
}
