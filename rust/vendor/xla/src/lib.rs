//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build sandbox cannot fetch or link the real PJRT runtime, so this
//! stub exposes the exact API surface `stocator::runtime::engine` compiles
//! against and fails at [`PjRtClient::cpu`]. `Kernels::load_or_fallback`
//! therefore always selects the pure-Rust fallback kernels; the XLA parity
//! tests skip gracefully. Swap in the real crate to re-enable the AOT path.

use std::fmt;

/// Error type mirroring xla-rs's; always carries a plain message here.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("PJRT runtime not available in this build (offline xla stub)".to_string())
}

/// A host literal (tensor value). Never materialised by the stub.
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// A device buffer returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// The PJRT client. [`PjRtClient::cpu`] is the stub's single failure point.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
