//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the subset this repository uses: an opaque [`Error`]
//! holding a rendered message chain, the [`Result`] alias, the [`Context`]
//! extension trait for `Result` and `Option`, and the `bail!` / `anyhow!`
//! macros. Error sources are flattened into the message at wrap time
//! (`"context: cause"`), which is all the callers ever display.

use std::fmt;

/// An opaque error: a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }

    fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{}: {}", context, self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:?}"), "boom 42");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn from_std_error() {
        fn f() -> Result<()> {
            let _ = std::str::from_utf8(&[0xff])?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
