//! Golden REST-op snapshots for the paper's one-object job (§2.3 /
//! Table 2 shape), per deployment scenario — the accounting safety net
//! under the streaming I/O API.
//!
//! The redesign's core invariance claim: *how* a caller feeds bytes into
//! an `FsOutputStream` (one whole-buffer `write_all`, or many small
//! `write` calls) must never change which REST operations reach the
//! store, in which order. These tests run the same one-object job twice
//! per scenario — once through the whole-buffer wrappers (the legacy
//! pre-stream call shape) and once streaming in 7-byte chunks — and
//! assert byte-for-byte identical REST traces, plus an exact hardcoded
//! sequence for Stocator (whose Table 2 row is the paper's headline) and
//! per-kind op-count snapshots.

use std::sync::Arc;
use stocator::committer::{Committer, JobContext, TaskAttemptContext};
use stocator::connectors::naming::AttemptId;
use stocator::fs::{FileSystem, FsInputStream, FsOutputStream, OpCtx, Path};
use stocator::harness::{run_cell, Scenario, Sizing, Workload};
use stocator::metrics::{OpCounts, OpKind};
use stocator::objectstore::{
    BackendKind, ConsistencyModel, FaultOp, FaultSpec, LatencyModel, ObjectStore, RetryPolicy,
    StoreConfig,
};
use stocator::simclock::SimInstant;

const PART_BYTES: usize = 200;
/// Small enough that the 200-byte part multiparts under S3a fast upload
/// (the harness scales `fs.s3a.multipart.size` the same way).
const MULTIPART_SIZE: u64 = 64;

fn build(scenario: Scenario) -> (Arc<ObjectStore>, Arc<dyn FileSystem>) {
    build_with_readahead(scenario, 0)
}

fn build_with_readahead(
    scenario: Scenario,
    readahead: u64,
) -> (Arc<ObjectStore>, Arc<dyn FileSystem>) {
    build_with(scenario, readahead, FaultSpec::none(), 0)
}

fn build_with_faults(
    scenario: Scenario,
    faults: FaultSpec,
    retries: u32,
) -> (Arc<ObjectStore>, Arc<dyn FileSystem>) {
    build_with(scenario, 0, faults, retries)
}

fn build_with(
    scenario: Scenario,
    readahead: u64,
    faults: FaultSpec,
    retries: u32,
) -> (Arc<ObjectStore>, Arc<dyn FileSystem>) {
    let store = ObjectStore::new(StoreConfig {
        latency: LatencyModel::paper_testbed(),
        consistency: ConsistencyModel::strong(),
        min_part_size: 0,
        seed: 0,
        backend: BackendKind::Mem,
        readahead,
        faults,
        retry: RetryPolicy::with_retries(retries),
        ..StoreConfig::default()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = scenario.connector(store.clone(), MULTIPART_SIZE);
    (store, fs)
}

/// Keep only trace lines that are REST operations (every REST line names
/// its HTTP verb; intercepted *no-op* lines never do).
fn rest_ops(trace: &[String]) -> Vec<String> {
    const VERBS: [&str; 6] = ["PUT ", "GET ", "HEAD ", "DELETE ", "COPY ", "POST "];
    trace
        .iter()
        .filter(|l| VERBS.iter().any(|v| l.contains(v)))
        .cloned()
        .collect()
}

/// The one-object job + read-back, writing the part through `write`
/// calls of `chunk` bytes (`usize::MAX` = the whole-buffer wrapper
/// shape). Returns (REST trace, virtual elapsed micros, op counts).
fn one_object_job(
    store: &ObjectStore,
    fs: &dyn FileSystem,
    scenario: Scenario,
    chunk: usize,
) -> (Vec<String>, u64, OpCounts) {
    let before = store.counters();
    let mut ctx = OpCtx::traced(SimInstant::EPOCH);
    let out = Path::parse(&format!("{}://res/data.txt", scenario.scheme())).unwrap();
    let job = JobContext::new(out.clone());
    let committer = Committer::new(scenario.algorithm());
    committer.setup_job(fs, &job, &mut ctx).unwrap();
    let task = TaskAttemptContext::new(&job, AttemptId::new("201512062056", "0000", 0, 0));
    committer.setup_task(fs, &task, &mut ctx).unwrap();
    let data = vec![7u8; PART_BYTES];
    if chunk >= PART_BYTES {
        committer
            .write_part(fs, &task, "part-00000", data, &mut ctx)
            .unwrap();
    } else {
        let mut stream = committer
            .create_part(fs, &task, "part-00000", &mut ctx)
            .unwrap();
        for piece in data.chunks(chunk) {
            stream.write(piece, &mut ctx).unwrap();
        }
        stream.close(&mut ctx).unwrap();
    }
    if committer.needs_task_commit(fs, &task, &mut ctx) {
        committer.commit_task(fs, &task, &mut ctx).unwrap();
    }
    committer.commit_job(fs, &job, &mut ctx).unwrap();
    // Read-back: discover the dataset, read its one part end to end.
    let parts: Vec<_> = fs
        .list_status(&out, &mut ctx)
        .unwrap()
        .into_iter()
        .filter(|s| !s.is_dir && !s.path.name().starts_with('_'))
        .collect();
    assert_eq!(parts.len(), 1, "{scenario:?}: {parts:?}");
    let read = fs.read_all(&parts[0].path, &mut ctx).unwrap();
    assert_eq!(read.len(), PART_BYTES, "{scenario:?}");
    let elapsed = ctx.elapsed.as_micros();
    (
        rest_ops(&ctx.take_trace()),
        elapsed,
        store.counters().since(&before),
    )
}

/// Whole-buffer wrapper path vs 7-byte streaming path: identical REST
/// sequences, for every scenario. This is the "before/after the stream
/// refactor" proof — `write_all` IS the legacy call shape.
#[test]
fn streaming_preserves_rest_sequences_in_every_scenario() {
    for scenario in Scenario::ALL {
        let (store_w, fs_w) = build(scenario);
        let (whole, _, whole_ops) = one_object_job(&store_w, &*fs_w, scenario, usize::MAX);
        let (store_s, fs_s) = build(scenario);
        let (streamed, _, streamed_ops) = one_object_job(&store_s, &*fs_s, scenario, 7);
        assert!(!whole.is_empty(), "{scenario:?} produced no REST ops");
        assert_eq!(
            whole, streamed,
            "{scenario:?}: REST sequence must not depend on write chunking"
        );
        assert_eq!(whole_ops, streamed_ops, "{scenario:?}: op counts diverged");
    }
}

/// The job is fully deterministic: re-running it reproduces the same
/// trace, the same counts and the same virtual runtime.
#[test]
fn one_object_job_is_deterministic() {
    for scenario in Scenario::ALL {
        let (store_a, fs_a) = build(scenario);
        let a = one_object_job(&store_a, &*fs_a, scenario, usize::MAX);
        let (store_b, fs_b) = build(scenario);
        let b = one_object_job(&store_b, &*fs_b, scenario, usize::MAX);
        assert_eq!(a.0, b.0, "{scenario:?} trace");
        assert_eq!(a.1, b.1, "{scenario:?} virtual runtime");
        assert_eq!(a.2, b.2, "{scenario:?} op counts");
    }
}

/// Front-end striping is invisible to the accounting: the one-object job
/// run over the legacy single-mutex layout (`stripes: 1`) and over the
/// sharded front end (`stripes: 16`, the default) produces byte-identical
/// REST traces, op counts and virtual runtimes, for every scenario. The
/// lock layout is a concurrency detail, never a semantics one.
#[test]
fn front_end_striping_never_changes_the_golden_accounting() {
    let build_striped = |scenario: Scenario, stripes: usize| {
        let store = ObjectStore::new(StoreConfig {
            latency: LatencyModel::paper_testbed(),
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            stripes,
            ..StoreConfig::default()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = scenario.connector(store.clone(), MULTIPART_SIZE);
        (store, fs)
    };
    for scenario in Scenario::ALL {
        let (store_l, fs_l) = build_striped(scenario, 1);
        let legacy = one_object_job(&store_l, &*fs_l, scenario, usize::MAX);
        let (store_s, fs_s) = build_striped(scenario, 16);
        let sharded = one_object_job(&store_s, &*fs_s, scenario, usize::MAX);
        assert_eq!(legacy.0, sharded.0, "{scenario:?} trace");
        assert_eq!(legacy.1, sharded.1, "{scenario:?} virtual runtime");
        assert_eq!(legacy.2, sharded.2, "{scenario:?} op counts");
    }
}

/// Virtual runtime is chunking-invariant everywhere: chunked-transfer
/// writers (Stocator) and fast upload pay no per-chunk cost, and the
/// spool-to-disk connectors charge disk time on the cumulative spool
/// (telescoping), so the total — including the scale-threshold decision —
/// never depends on how callers split their writes.
#[test]
fn chunking_does_not_change_virtual_runtime() {
    for scenario in Scenario::ALL {
        let (store_w, fs_w) = build(scenario);
        let (_, whole_us, _) = one_object_job(&store_w, &*fs_w, scenario, usize::MAX);
        let (store_s, fs_s) = build(scenario);
        let (_, streamed_us, _) = one_object_job(&store_s, &*fs_s, scenario, 7);
        assert_eq!(whole_us, streamed_us, "{scenario:?}");
    }
}

/// The exact Stocator sequence (paper Table 2's headline row): three
/// PUTs to write the dataset — marker, part (intercepted to its final
/// attempt-qualified name), `_SUCCESS` — then HEAD + one listing + one
/// GET to read it back. No COPY, no DELETE, ever.
#[test]
fn stocator_golden_sequence() {
    let (store, fs) = build(Scenario::Stocator);
    let (ops, _, counts) = one_object_job(&store, &*fs, Scenario::Stocator, usize::MAX);
    let expect = vec![
        "stocator: PUT res/data.txt (dataset marker)",
        "stocator: (intercept) PUT res/data.txt/part-00000_attempt_201512062056_0000_m_000000_0",
        "stocator: PUT res/data.txt/_SUCCESS",
        "stocator: HEAD res/data.txt/_SUCCESS",
        "stocator: GET container ?prefix=data.txt/&delimiter=/",
        "stocator: GET res/data.txt/part-00000_attempt_201512062056_0000_m_000000_0",
    ];
    assert_eq!(ops, expect);
    assert_eq!(counts.get(OpKind::PutObject), 3);
    assert_eq!(counts.get(OpKind::HeadObject), 1);
    assert_eq!(counts.get(OpKind::GetObject), 1);
    assert_eq!(counts.get(OpKind::GetContainer), 1);
    assert_eq!(counts.get(OpKind::CopyObject), 0);
    assert_eq!(counts.get(OpKind::DeleteObject), 0);
    assert_eq!(counts.bytes_written, PART_BYTES as u64 + {
        // the _SUCCESS manifest: header + one part line
        let manifest = format!(
            "stocator-manifest-v1\npart-00000\tattempt_201512062056_0000_m_000000_0\t{PART_BYTES}\n"
        );
        manifest.len() as u64
    });
    assert_eq!(counts.bytes_copied, 0);
}

/// The paper's scenario ordering (Table 2): Stocator ≪ Hadoop-Swift <
/// S3a on total REST ops for the same logical job; fast upload turns the
/// one part PUT into initiate + ceil(200/64)=4 parts + complete.
#[test]
fn scenario_op_totals_keep_paper_ordering() {
    let total = |scenario: Scenario| {
        let (store, fs) = build(scenario);
        let (_, _, counts) = one_object_job(&store, &*fs, scenario, usize::MAX);
        counts.total()
    };
    let st = total(Scenario::Stocator);
    let sw = total(Scenario::HadoopSwiftBase);
    let s3 = total(Scenario::S3aBase);
    assert!(st < sw / 3, "stocator {st} vs swift {sw}");
    assert!(sw < s3, "swift {sw} vs s3a {s3}");

    // Fast upload: multipart ops appear, named per part.
    let (store, fs) = build(Scenario::S3aCv2Fu);
    let (ops, _, _) = one_object_job(&store, &*fs, Scenario::S3aCv2Fu, usize::MAX);
    let initiates = ops.iter().filter(|l| l.contains("?uploads (initiate)")).count();
    let parts = ops.iter().filter(|l| l.contains("?partNumber=")).count();
    let completes = ops.iter().filter(|l| l.contains("(complete)")).count();
    assert_eq!((initiates, parts, completes), (1, 4, 1));
}

// ---- readahead snapshots ---------------------------------------------------
//
// The GET-coalescing half of the accounting safety net: a many-small-reads
// job must issue ≥4× fewer GETs with readahead on — on every connector —
// while returning identical bytes, and everything the paper's tables pin
// (the one-object REST sequences, virtual runtimes with readahead off)
// must stay byte-identical.

/// Readahead window for the snapshots below (simulated bytes).
const READAHEAD: u64 = 64;
/// One small-record input object, read in `step`-byte sequential slices.
const SMALL_OBJ_BYTES: usize = 400;

/// Write one plain input object, then read it back in `step`-byte
/// sequential `read_range` calls — the terasort-sampling/small-record
/// shape. Returns (read-phase REST trace, read-phase op counts,
/// read-phase virtual micros); the bytes read back are asserted
/// byte-identical to the object inside.
fn many_small_reads(
    store: &ObjectStore,
    fs: &dyn FileSystem,
    scenario: Scenario,
    step: usize,
) -> (Vec<String>, OpCounts, u64) {
    let path = Path::parse(&format!("{}://res/in/part-00000", scenario.scheme())).unwrap();
    let data: Vec<u8> = (0..SMALL_OBJ_BYTES).map(|i| (i % 251) as u8).collect();
    let mut setup = OpCtx::new(SimInstant::EPOCH);
    fs.write_all(&path, data.clone(), true, &mut setup).unwrap();
    let before = store.counters();
    let mut ctx = OpCtx::traced(SimInstant::EPOCH);
    let mut input = fs.open(&path, &mut ctx).unwrap();
    let mut got = Vec::new();
    for off in (0..SMALL_OBJ_BYTES).step_by(step) {
        got.extend(input.read_range(off as u64, step as u64, &mut ctx).unwrap());
    }
    assert_eq!(got, data, "{scenario:?}: readback must be byte-identical");
    let elapsed = ctx.elapsed.as_micros();
    (
        rest_ops(&ctx.take_trace()),
        store.counters().since(&before),
        elapsed,
    )
}

/// Readahead on vs off, every scenario: identical bytes (asserted inside
/// the job), ≥4× fewer GET ops, identical bytes over the wire (a pure
/// sequential scan fetches the object exactly once either way), no change
/// to any other op kind, and a strictly smaller virtual runtime.
#[test]
fn readahead_coalesces_many_small_reads_on_every_connector() {
    for scenario in Scenario::ALL {
        let (store_off, fs_off) = build(scenario);
        let (_, off, t_off) = many_small_reads(&store_off, &*fs_off, scenario, 8);
        let (store_on, fs_on) = build_with_readahead(scenario, READAHEAD);
        let (_, on, t_on) = many_small_reads(&store_on, &*fs_on, scenario, 8);
        let (gets_off, gets_on) = (off.get(OpKind::GetObject), on.get(OpKind::GetObject));
        assert!(
            gets_on * 4 <= gets_off,
            "{scenario:?}: {gets_on} GETs with readahead vs {gets_off} without — want ≥4x fewer"
        );
        assert_eq!(
            on.bytes_read, off.bytes_read,
            "{scenario:?}: a sequential scan must not over-fetch"
        );
        for kind in [
            OpKind::HeadObject,
            OpKind::HeadContainer,
            OpKind::PutObject,
            OpKind::CopyObject,
            OpKind::DeleteObject,
            OpKind::GetContainer,
        ] {
            assert_eq!(
                on.get(kind),
                off.get(kind),
                "{scenario:?}: readahead must only touch GETs ({kind:?})"
            );
        }
        assert!(
            t_on < t_off,
            "{scenario:?}: readahead runtime {t_on}us must beat naive {t_off}us"
        );
    }
}

/// The exact Stocator fill sequence: window 64 doubles to 128 then 256 on
/// sequential misses (clamped at EOF below), so 50 reads are 3 ranged
/// GETs — still no HEAD before GET (§3.4; the first fill warms the
/// cache).
#[test]
fn stocator_readahead_golden_fill_sequence() {
    let (store, fs) = build_with_readahead(Scenario::Stocator, READAHEAD);
    let (trace, counts, _) = many_small_reads(&store, &*fs, Scenario::Stocator, 8);
    let expect = vec![
        "stocator: GET res/in/part-00000 bytes=0+64",
        "stocator: GET res/in/part-00000 bytes=64+128",
        "stocator: GET res/in/part-00000 bytes=192+256",
    ];
    assert_eq!(trace, expect);
    assert_eq!(counts.get(OpKind::GetObject), 3);
    assert_eq!(counts.get(OpKind::HeadObject), 0, "no HEAD before GET (§3.4)");
    assert_eq!(counts.bytes_read, SMALL_OBJ_BYTES as u64, "last fill clamps at EOF");
}

/// Caller chunking must not change the fills: 8-byte and 16-byte read
/// steps hit the same window boundaries, so the REST sequences and the
/// virtual runtimes are identical — the read-side analogue of the
/// write-chunking invariance above.
#[test]
fn readahead_fills_are_chunking_invariant() {
    for scenario in Scenario::ALL {
        let (store_a, fs_a) = build_with_readahead(scenario, READAHEAD);
        let (trace_a, ops_a, t_a) = many_small_reads(&store_a, &*fs_a, scenario, 8);
        let (store_b, fs_b) = build_with_readahead(scenario, READAHEAD);
        let (trace_b, ops_b, t_b) = many_small_reads(&store_b, &*fs_b, scenario, 16);
        assert_eq!(trace_a, trace_b, "{scenario:?}: fill sequence must not depend on read chunking");
        assert_eq!(ops_a, ops_b, "{scenario:?}");
        assert_eq!(t_a, t_b, "{scenario:?}: virtual runtime must be chunking-invariant");
    }
}

/// Whole-object reads bypass the window, so the paper's one-object job —
/// Table 2's REST sequences, including the exact Stocator row — is
/// byte-identical whether the readahead knob is on or off.
#[test]
fn one_object_job_rest_sequence_is_readahead_invariant() {
    for scenario in Scenario::ALL {
        let (store_off, fs_off) = build(scenario);
        let (off, t_off, ops_off) = one_object_job(&store_off, &*fs_off, scenario, usize::MAX);
        let (store_on, fs_on) = build_with_readahead(scenario, READAHEAD);
        let (on, t_on, ops_on) = one_object_job(&store_on, &*fs_on, scenario, usize::MAX);
        assert_eq!(off, on, "{scenario:?}: Table 2 sequence must not move");
        assert_eq!(t_off, t_on, "{scenario:?}: virtual runtime must not move");
        assert_eq!(ops_off, ops_on, "{scenario:?}");
    }
}

// ---- transient-fault snapshots ---------------------------------------------
//
// The fault-plane half of the accounting safety net: one injected
// transient PUT fault per connector produces an EXACT golden retry
// sequence — the baseline trace with the failed request inserted — and
// an exactly priced recovery (the failed op's full duration + the
// backoff), with per-connector resume semantics visible in the wire-byte
// accounting (spool re-PUT and chunked-PUT restart re-send the whole
// object; fast upload re-sends one part).

/// With the fault plane explicitly at its defaults (empty spec, zero
/// retries), every scenario's trace, runtime and op counts are
/// byte-identical to the stock build — the defaults knob is a no-op.
#[test]
fn fault_plane_defaults_change_nothing() {
    for scenario in Scenario::ALL {
        let (store_a, fs_a) = build(scenario);
        let a = one_object_job(&store_a, &*fs_a, scenario, usize::MAX);
        let (store_b, fs_b) = build_with_faults(scenario, FaultSpec::none(), 0);
        let b = one_object_job(&store_b, &*fs_b, scenario, usize::MAX);
        assert_eq!(a.0, b.0, "{scenario:?} trace");
        assert_eq!(a.1, b.1, "{scenario:?} virtual runtime");
        assert_eq!(a.2, b.2, "{scenario:?} op counts");
    }
}

/// One injected transient fault on the part write, `--retries 1`, every
/// connector family: the REST trace is EXACTLY the baseline with the
/// failed request inserted before its retry, the virtual runtime grows
/// by EXACTLY the failed op + backoff, and the extra wire bytes are the
/// connector's re-send unit — full object for the spool connectors and
/// for Stocator's unresumable chunked PUT, one part for fast upload.
#[test]
fn injected_put_fault_golden_retry_sequences() {
    let attempt_part_key =
        "data.txt/_temporary/0/_temporary/attempt_201512062056_0000_m_000000_0/part-00000";
    let stoc_final_key = "data.txt/part-00000_attempt_201512062056_0000_m_000000_0";
    struct Case {
        scenario: Scenario,
        spec: FaultSpec,
        /// The success line of the faulted operation (the failed twin is
        /// inserted right before it).
        target: String,
        /// Simulated payload bytes the failed request burned = the
        /// connector's re-send unit.
        failed_bytes: u64,
    }
    let cases = vec![
        Case {
            scenario: Scenario::HadoopSwiftBase,
            spec: FaultSpec::one(FaultOp::Put, attempt_part_key, 1),
            target: format!("swift: PUT res/{attempt_part_key}"),
            failed_bytes: PART_BYTES as u64,
        },
        Case {
            scenario: Scenario::S3aBase,
            spec: FaultSpec::one(FaultOp::Put, attempt_part_key, 1),
            target: format!("s3a: PUT res/{attempt_part_key}"),
            failed_bytes: PART_BYTES as u64,
        },
        Case {
            scenario: Scenario::Stocator,
            spec: FaultSpec::one(FaultOp::Put, stoc_final_key, 1),
            target: format!("stocator: (intercept) PUT res/{stoc_final_key}"),
            failed_bytes: PART_BYTES as u64,
        },
        Case {
            // Fast upload: fail the SECOND part PUT — only that part is
            // re-sent; initiate, part 1 and part 3 are untouched.
            scenario: Scenario::S3aCv2Fu,
            spec: FaultSpec::one(FaultOp::UploadPart, attempt_part_key, 2),
            target: format!("s3a: PUT res/{attempt_part_key}?partNumber=2"),
            failed_bytes: MULTIPART_SIZE,
        },
    ];
    for case in &cases {
        let (store_base, fs_base) = build(case.scenario);
        let (baseline, t_base, ops_base) =
            one_object_job(&store_base, &*fs_base, case.scenario, usize::MAX);
        let (store_f, fs_f) = build_with_faults(case.scenario, case.spec.clone(), 1);
        let (faulted, t_fault, ops_fault) =
            one_object_job(&store_f, &*fs_f, case.scenario, usize::MAX);

        // Exact golden trace: baseline + the failed request, in place.
        let idx = baseline
            .iter()
            .position(|l| l == &case.target)
            .unwrap_or_else(|| panic!("{:?}: target line missing in {baseline:?}", case.scenario));
        let mut expected = baseline.clone();
        expected.insert(idx, format!("{} (503 transient)", case.target));
        assert_eq!(faulted, expected, "{:?}", case.scenario);

        // Exact recovery price: the failed op's full duration + backoff.
        let lat = LatencyModel::paper_testbed();
        let extra = lat.op_duration(OpKind::PutObject, case.failed_bytes, 0)
            + RetryPolicy::with_retries(1).backoff(1);
        assert_eq!(
            t_fault,
            t_base + extra.as_micros(),
            "{:?}: recovery must cost exactly one failed op + backoff",
            case.scenario
        );

        // Wire bytes: the re-send unit, and exactly one extra PUT op.
        assert_eq!(
            ops_fault.bytes_written,
            ops_base.bytes_written + case.failed_bytes,
            "{:?}",
            case.scenario
        );
        assert_eq!(
            ops_fault.get(OpKind::PutObject),
            ops_base.get(OpKind::PutObject) + 1,
            "{:?}",
            case.scenario
        );
        for kind in [
            OpKind::HeadObject,
            OpKind::HeadContainer,
            OpKind::GetObject,
            OpKind::CopyObject,
            OpKind::DeleteObject,
            OpKind::GetContainer,
        ] {
            assert_eq!(
                ops_fault.get(kind),
                ops_base.get(kind),
                "{:?}: a PUT fault must only add PUT-class ops ({kind:?})",
                case.scenario
            );
        }
    }
    // THE paper-footnote contrast: Stocator's unresumable chunked PUT
    // re-sends the whole object where fast upload re-sends one part.
    assert!(cases[2].failed_bytes > cases[3].failed_bytes);
    assert_eq!(cases[2].failed_bytes, PART_BYTES as u64);
    assert_eq!(cases[3].failed_bytes, MULTIPART_SIZE);
}

/// One injected 429 THROTTLE on Stocator's chunked PUT, `--retries 1`:
/// the golden trace is the baseline with a `(429 throttle)` line
/// inserted, recovery costs EXACTLY one base PUT latency (the body was
/// shed — no transfer time) + the flat Retry-After, and — the contrast
/// with a 503 — the wire-byte accounting is UNCHANGED: a throttled PUT
/// puts zero payload bytes on the wire.
#[test]
fn injected_throttle_golden_retry_sequence() {
    let stoc_final_key = "data.txt/part-00000_attempt_201512062056_0000_m_000000_0";
    let scenario = Scenario::Stocator;
    let (store_base, fs_base) = build(scenario);
    let (baseline, t_base, ops_base) = one_object_job(&store_base, &*fs_base, scenario, usize::MAX);
    let spec = FaultSpec::parse(&format!("put:{stoc_final_key}@1!429")).unwrap();
    let (store_f, fs_f) = build_with_faults(scenario, spec, 1);
    let (faulted, t_fault, ops_fault) = one_object_job(&store_f, &*fs_f, scenario, usize::MAX);

    let target = format!("stocator: (intercept) PUT res/{stoc_final_key}");
    let idx = baseline
        .iter()
        .position(|l| l == &target)
        .unwrap_or_else(|| panic!("target line missing in {baseline:?}"));
    let mut expected = baseline.clone();
    expected.insert(idx, format!("{target} (429 throttle)"));
    assert_eq!(faulted, expected);

    // Recovery price: base PUT latency (zero transfer) + flat Retry-After.
    let lat = LatencyModel::paper_testbed();
    let policy = RetryPolicy::with_retries(1);
    let extra = lat.op_duration(OpKind::PutObject, 0, 0).as_micros() + policy.retry_after_us;
    assert_eq!(t_fault, t_base + extra, "throttle recovery = base latency + Retry-After");

    // The op is counted; the bytes are NOT (contrast with the 503 case).
    assert_eq!(ops_fault.get(OpKind::PutObject), ops_base.get(OpKind::PutObject) + 1);
    assert_eq!(
        ops_fault.bytes_written, ops_base.bytes_written,
        "a throttled PUT must put zero payload bytes on the wire"
    );
}

/// Probabilistic fault rates drive whole cells deterministically: the
/// same seeded `p=` schedule reproduces identical op counts and
/// runtimes run over run, only ever ADDS retry ops relative to the
/// fault-free cell, and the job output still validates under a
/// sufficient retry budget.
#[test]
fn probabilistic_fault_cells_are_deterministic_and_recoverable() {
    let mut sizing = Sizing::small();
    let base = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    assert!(base.valid, "{}", base.validation);
    sizing.faults = FaultSpec::parse("put@p=0.05").unwrap();
    sizing.retries = 5;
    let a = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    let b = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    assert!(a.valid, "{}", a.validation);
    assert_eq!(a.ops, b.ops, "seeded p= schedules replay exactly");
    assert_eq!(a.runtime_mean_s, b.runtime_mean_s);
    assert!(
        a.ops.total() >= base.ops.total(),
        "probabilistic faults can only add retry ops"
    );
    assert!(
        a.ops.bytes_written >= base.ops.bytes_written,
        "503-class re-sends never shrink wire bytes"
    );
}

/// The observability plane is a pure observer: the one-object job run
/// over the wire against a gateway with the plane ON vs OFF — on BOTH
/// server cores, fault-free AND with an injected transient PUT fault —
/// produces byte-identical REST traces, virtual runtimes and op counts.
/// Histograms, the trace ring and the sweep stats may record whatever
/// they like; they must never move a number a client can see.
#[test]
fn observability_never_changes_op_counts_or_virtual_runtimes() {
    use stocator::gateway::{GatewayConfig, GatewayMode, GatewayServer};
    use stocator::objectstore::backend::ShardedMemBackend;

    let stoc_final_key = "data.txt/part-00000_attempt_201512062056_0000_m_000000_0";
    let run = |mode: GatewayMode, observability: bool, faulted: bool| {
        // Fresh gateway + fresh served store per run, so the A and B
        // sides start from identical (empty) worlds.
        let gw = GatewayServer::bind_with(
            "127.0.0.1:0",
            Arc::new(ShardedMemBackend::new(4)),
            GatewayConfig {
                mode,
                observability,
                ..GatewayConfig::default()
            },
        )
        .expect("bind gateway")
        .spawn();
        let store = ObjectStore::new(StoreConfig {
            latency: LatencyModel::paper_testbed(),
            consistency: ConsistencyModel::strong(),
            min_part_size: 0,
            seed: 0,
            backend: BackendKind::Http {
                addr: gw.addr().to_string(),
                ns: None,
            },
            faults: if faulted {
                FaultSpec::one(FaultOp::Put, stoc_final_key, 1)
            } else {
                FaultSpec::none()
            },
            retry: RetryPolicy::with_retries(u32::from(faulted)),
            ..StoreConfig::default()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Scenario::Stocator.connector(store.clone(), MULTIPART_SIZE);
        let out = one_object_job(&store, &*fs, Scenario::Stocator, usize::MAX);
        gw.shutdown();
        out
    };

    for mode in [GatewayMode::Threaded, GatewayMode::Reactor] {
        for faulted in [false, true] {
            let on = run(mode, true, faulted);
            let off = run(mode, false, faulted);
            assert!(!on.0.is_empty(), "{mode:?} produced no REST ops");
            if faulted {
                assert!(
                    on.0.iter().any(|l| l.contains("(503 transient)")),
                    "{mode:?}: the injected fault must actually fire"
                );
            }
            assert_eq!(on.0, off.0, "{mode:?} faulted={faulted}: trace moved");
            assert_eq!(on.1, off.1, "{mode:?} faulted={faulted}: virtual runtime moved");
            assert_eq!(on.2, off.2, "{mode:?} faulted={faulted}: op counts moved");
        }
    }
}

/// Whole-cell determinism: a full Teragen cell (driver, committer,
/// connector, store) reproduces identical op counts and virtual runtime
/// run over run — the cell-level half of the accounting snapshot.
#[test]
fn teragen_cell_runtime_and_ops_are_reproducible() {
    let sizing = Sizing::small();
    let a = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    let b = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    assert!(a.valid, "{}", a.validation);
    assert_eq!(a.ops, b.ops);
    assert_eq!(a.runtime_mean_s, b.runtime_mean_s);
    assert_eq!(a.ops.get(OpKind::CopyObject), 0);
    assert_eq!(a.ops.get(OpKind::DeleteObject), 0);
}
