//! Wire chaos plane + idempotent retry protocol, end to end: duplicate
//! `x-request-id`s replay the cached response instead of re-executing,
//! `HttpBackend` survives killed / truncated / reset / stalled
//! connections on both server cores with zero correctness violations,
//! and the stress plane proves it under real concurrency.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use stocator::gateway::http::{read_response, write_request, Headers, Response};
use stocator::gateway::{
    ChaosConfig, GatewayConfig, GatewayHandle, GatewayMode, GatewayServer, HttpBackend,
};
use stocator::loadgen::{run_stress, StressConfig};
use stocator::objectstore::backend::{Backend, ShardedMemBackend};
use stocator::objectstore::{Metadata, Object};
use stocator::simclock::SimInstant;

/// Spawn a gateway over a fresh sharded store with the given knobs.
fn gateway(mode: GatewayMode, tweak: impl FnOnce(&mut GatewayConfig)) -> GatewayHandle {
    let mut config = GatewayConfig { mode, ..GatewayConfig::default() };
    tweak(&mut config);
    GatewayServer::bind_with("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)), config)
        .expect("bind gateway")
        .spawn()
}

/// One raw round-trip (with body) on a dedicated connection.
fn raw(addr: &str, method: &str, target: &str, headers: &Headers, body: &[u8]) -> Response {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    write_request(&mut write_half, method, target, headers, body).expect("write");
    read_response(&mut BufReader::new(stream)).expect("response")
}

fn with_id(id: &str) -> Headers {
    let mut h = Headers::new();
    h.push("x-request-id", id);
    h
}

fn obj(data: &[u8]) -> Object {
    Object::new(data.to_vec(), Metadata::new(), SimInstant(0))
}

#[test]
fn request_id_replay_returns_the_cached_response_verbatim() {
    let handle = gateway(GatewayMode::Reactor, |_| {});
    let addr = handle.addr().to_string();
    // First execution: the container is created for real.
    let first = raw(&addr, "PUT", "/v1/res", &with_id("deadbeef01"), b"");
    assert_eq!(first.status, 201);
    assert_eq!(first.headers.get("x-request-replayed"), None);
    // Duplicate id on a NEW connection: the 201 comes back from the
    // replay cache (marked), NOT the 409 a re-execution would produce.
    let dup = raw(&addr, "PUT", "/v1/res", &with_id("deadbeef01"), b"");
    assert_eq!(dup.status, 201, "duplicate id must replay, not re-execute");
    assert_eq!(dup.headers.get("x-request-replayed"), Some("true"));
    assert_eq!(handle.replayed_responses(), 1);
    // Without an id the same request really re-executes: 409.
    let bare = raw(&addr, "PUT", "/v1/res", &Headers::new(), b"");
    assert_eq!(bare.status, 409, "unstamped requests are not deduplicated");
    // Object PUT: the replayed response preserves the ORIGINAL result
    // (x-replaced: false), even though by now the key exists — exactly
    // what a client that re-sent a lost-response PUT must see.
    let put = raw(&addr, "PUT", "/v1/res/k", &with_id("feedface02"), b"hello");
    assert_eq!(put.status, 201);
    assert_eq!(put.headers.get("x-replaced"), Some("false"));
    let replay = raw(&addr, "PUT", "/v1/res/k", &with_id("feedface02"), b"hello");
    assert_eq!(replay.status, 201);
    assert_eq!(replay.headers.get("x-replaced"), Some("false"));
    assert_eq!(replay.headers.get("x-request-replayed"), Some("true"));
    assert_eq!(replay.headers.get("etag"), put.headers.get("etag"));
    // A genuinely fresh id re-executes and observes the replacement.
    let fresh = raw(&addr, "PUT", "/v1/res/k", &with_id("0badc0de03"), b"hello");
    assert_eq!(fresh.headers.get("x-replaced"), Some("true"));
    assert_eq!(handle.replayed_responses(), 2);
    handle.shutdown();
}

/// Run a small verified workload through a chaos-armed gateway: every
/// operation must succeed with exact bytes, and the run must have both
/// injected faults and client retries (else the test proved nothing).
fn survive_chaos(handle: &GatewayHandle, client_seed: u64) {
    let addr = handle.addr().to_string();
    let b = HttpBackend::connect(&addr, None)
        .expect("connect")
        .with_rng_seed(client_seed);
    b.create_container("res").expect("create container under chaos");
    for i in 0..30u8 {
        let key = format!("k/{i:02}");
        let data = vec![i ^ 0x5A; 64 + i as usize];
        b.put("res", &key, obj(&data)).expect("put under chaos");
        let got = b.get("res", &key).expect("get under chaos");
        assert_eq!(&**got.data, &data[..], "byte round-trip through chaos");
    }
    b.delete("res", "k/00").expect("delete under chaos");
    let page = b.list_page("res", "k/", None, 100).expect("list under chaos");
    assert_eq!(page.entries.len(), 29, "listing reflects exactly the surviving keys");
    assert!(
        handle.chaos_injected() >= 1,
        "chaos plane never fired — the test exercised nothing"
    );
    assert!(
        b.retried_sends() >= 1,
        "no send was ever retried despite {} injected faults",
        handle.chaos_injected()
    );
}

#[test]
fn kill_response_chaos_is_survived_on_the_reactor_core() {
    // ~97 requests at p=0.2: P(no fault at all) ≈ 4e-10 — deterministic
    // in practice, and the draws themselves are seeded anyway.
    let handle = gateway(GatewayMode::Reactor, |c| {
        c.chaos = ChaosConfig::parse("kill-response@p=0.2").unwrap();
    });
    survive_chaos(&handle, 0xA11CE);
    assert!(handle.replayed_responses() >= 1, "a killed mutation must hit the replay cache");
    handle.shutdown();
}

#[test]
fn truncate_and_reset_chaos_are_survived_on_the_threaded_core() {
    let handle = gateway(GatewayMode::Threaded, |c| {
        c.chaos = ChaosConfig::parse("truncate@p=0.15,reset@p=0.15").unwrap();
        c.chaos.seed = 11;
    });
    survive_chaos(&handle, 0xB0B);
    handle.shutdown();
}

#[test]
fn stall_chaos_holds_the_response_past_the_client_read_deadline() {
    let handle = gateway(GatewayMode::Reactor, |c| {
        c.chaos = ChaosConfig::parse("stall@p=1").unwrap();
    });
    let addr = handle.addr().to_string();
    // A raw (timeout-free) reader sees the stall in full: no bytes for
    // the whole hold (longer than HttpBackend's 2s read timeout), then
    // a server-side close with the response never written.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
    let t0 = Instant::now();
    let result = read_response(&mut BufReader::new(stream));
    assert!(result.is_err(), "a stalled response must never arrive, got {result:?}");
    assert!(
        t0.elapsed() >= Duration::from_millis(2500),
        "stall released after only {:?} — a timing-out client would have seen it",
        t0.elapsed()
    );
    assert!(handle.chaos_injected() >= 1);
    handle.shutdown();
}

#[test]
fn stress_survives_wire_chaos_with_zero_violations() {
    let cfg = StressConfig {
        clients: 4,
        shards: 4,
        payload: 2048,
        seed: 7,
        ops_per_client: Some(40),
        matrix: false,
        bench_path: None,
        chaos: ChaosConfig::parse("kill-response@p=0.05,truncate@p=0.03,reset@p=0.03").unwrap(),
        ..StressConfig::default()
    };
    let report = run_stress(&cfg).expect("stress run");
    assert_eq!(
        report.run.violation_count, 0,
        "chaos must never corrupt results: {:?}",
        report.run.violations
    );
    assert_eq!(report.run.total_ops, 4 * 40, "every op completed despite chaos");
    assert!(report.run.retried_sends >= 1, "the hammer never hit a fault");
    assert!(
        report.run.replayed_responses >= 1,
        "no re-sent mutation was deduplicated ({} retries)",
        report.run.retried_sends
    );
}

#[test]
fn stress_over_a_local_fs_backend_is_clean() {
    let root = std::env::temp_dir().join(format!("stocator-chaos-fs-{}", std::process::id()));
    let cfg = StressConfig {
        clients: 2,
        shards: 2,
        payload: 512,
        ops_per_client: Some(15),
        matrix: false,
        bench_path: None,
        fs_root: Some(root.clone()),
        ..StressConfig::default()
    };
    let report = run_stress(&cfg).expect("fs-backed stress run");
    assert_eq!(report.run.violation_count, 0, "{:?}", report.run.violations);
    assert_eq!(report.run.total_ops, 30);
    assert_eq!(report.target, format!("in-process fs:{}", root.display()));
    // The store really was on disk.
    assert!(root.exists(), "fs root was never created");
    std::fs::remove_dir_all(&root).ok();
}
