//! Property-based protocol invariants across connectors: random job
//! shapes (task counts, attempt patterns, commit algorithms) must always
//! leave the dataset readable with exactly one complete part per task —
//! on every connector that claims correctness.

use std::sync::Arc;
use stocator::committer::{CommitAlgorithm, Committer, JobContext, TaskAttemptContext};
use stocator::connectors::naming::{self, AttemptId};
use stocator::connectors::{HadoopSwift, ReadStrategy, S3a, Stocator, StocatorConfig};
use stocator::fs::{FileSystem, OpCtx, Path};
use stocator::objectstore::{ObjectStore, StoreConfig};
use stocator::simclock::SimInstant;
use stocator::util::proptest::check;

fn fresh(scheme: &str, strategy: ReadStrategy) -> (Arc<ObjectStore>, Arc<dyn FileSystem>) {
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs: Arc<dyn FileSystem> = match scheme {
        "swift2d" => Stocator::new(
            store.clone(),
            StocatorConfig {
                read_strategy: strategy,
                cache_capacity: 128,
            },
        ),
        "swift" => HadoopSwift::new(store.clone()),
        "s3a" => S3a::new(store.clone(), Default::default()),
        _ => unreachable!(),
    };
    (store, fs)
}

/// Run a randomized job: each task runs 1-3 attempts; exactly one commits
/// (the last); non-winning attempts may or may not be aborted.
fn run_random_job(
    fs: &dyn FileSystem,
    scheme: &str,
    algorithm: CommitAlgorithm,
    tasks: u32,
    attempts_per_task: &[u32],
    abort_losers: bool,
) {
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let out = Path::parse(&format!("{scheme}://res/out")).unwrap();
    let job = JobContext::new(out);
    let committer = Committer::new(algorithm);
    committer.setup_job(fs, &job, &mut ctx).unwrap();
    for t in 0..tasks {
        let n_attempts = attempts_per_task[t as usize];
        for a in 0..n_attempts {
            let tac = TaskAttemptContext::new(&job, AttemptId::new("77", "0000", t, a));
            committer.setup_task(fs, &tac, &mut ctx).unwrap();
            committer
                .write_part(fs, &tac, &format!("part-{t:05}"), vec![t as u8 + 1; 40], &mut ctx)
                .unwrap();
        }
        let winner = n_attempts - 1;
        let wtac = TaskAttemptContext::new(&job, AttemptId::new("77", "0000", t, winner));
        committer.commit_task(fs, &wtac, &mut ctx).unwrap();
        if abort_losers {
            for a in 0..n_attempts - 1 {
                let ltac = TaskAttemptContext::new(&job, AttemptId::new("77", "0000", t, a));
                committer.abort_task(fs, &ltac, &mut ctx).unwrap();
            }
        }
    }
    committer.commit_job(fs, &job, &mut ctx).unwrap();
}

fn readable_parts(fs: &dyn FileSystem, scheme: &str) -> Vec<(String, u64)> {
    let mut ctx = OpCtx::new(SimInstant(1));
    let out = Path::parse(&format!("{scheme}://res/out")).unwrap();
    fs.list_status(&out, &mut ctx)
        .unwrap_or_default()
        .into_iter()
        .filter(|s| !s.is_dir && s.path.name().starts_with("part-"))
        .map(|s| (s.path.name().to_string(), s.len))
        .collect()
}

#[test]
fn random_jobs_yield_one_complete_part_per_task_everywhere() {
    check("protocol invariant", 40, |g| {
        let tasks = g.u32(1..6);
        let attempts: Vec<u32> = (0..tasks).map(|_| g.u32(1..4)).collect();
        let abort = g.bool();
        let algorithm = if g.bool() {
            CommitAlgorithm::V1
        } else {
            CommitAlgorithm::V2
        };
        for (scheme, strategy) in [
            ("swift2d", ReadStrategy::List),
            ("swift2d", ReadStrategy::Manifest),
            ("swift", ReadStrategy::List),
            ("s3a", ReadStrategy::List),
        ] {
            // The legacy connectors only guarantee correctness when losers
            // are aborted (v1) — which is exactly what Spark does when it
            // can. Skip the combination they never claimed to support.
            let abort = if scheme == "swift2d" { abort } else { true };
            let (_store, fs) = fresh(scheme, strategy);
            run_random_job(&*fs, scheme, algorithm, tasks, &attempts, abort);
            let mut parts = readable_parts(&*fs, scheme);
            parts.sort();
            assert_eq!(
                parts.len(),
                tasks as usize,
                "{scheme}/{strategy:?}/{algorithm:?} abort={abort}: {parts:?}"
            );
            for (i, (name, len)) in parts.iter().enumerate() {
                assert!(
                    name.starts_with(&format!("part-{i:05}")),
                    "{scheme}: unexpected part order {parts:?}"
                );
                assert_eq!(*len, 40, "{scheme}: truncated part {name}");
            }
        }
    });
}

#[test]
fn naming_roundtrip_fuzz() {
    check("naming codec fuzz", 300, |g| {
        let ds = g.object_path();
        let base = format!("part-{:05}", g.u32(0..100_000));
        let attempt = AttemptId::new(
            &format!("{}", g.u64() % 1_000_000_000_000),
            "0000",
            g.u32(0..1_000_000),
            g.u32(0..100),
        );
        let key = naming::stocator_final_key(&ds, &base, &attempt);
        let (b2, a2) = naming::parse_stocator_key(&ds, &key).expect("roundtrip");
        assert_eq!(b2, base);
        assert_eq!(a2, attempt);
        // And the HMRCC temp grammar classifies its own productions.
        let temp = format!("{ds}/_temporary/0/_temporary/{attempt}/{base}");
        match naming::classify(&temp).expect("classify") {
            naming::TempPath::TaskTempFile {
                dataset,
                attempt: a3,
                basename,
            } => {
                assert_eq!(dataset, ds);
                assert_eq!(a3, attempt);
                assert_eq!(basename, base);
            }
            other => panic!("misclassified {other:?}"),
        }
    });
}

#[test]
fn stocator_read_equals_manifest_read_after_clean_job() {
    // The two §3.2 options must agree whenever the job ran clean.
    check("list == manifest", 25, |g| {
        let tasks = g.u32(1..5);
        let attempts: Vec<u32> = (0..tasks).map(|_| g.u32(1..3)).collect();
        let (_s1, list_fs) = fresh("swift2d", ReadStrategy::List);
        let (_s2, man_fs) = fresh("swift2d", ReadStrategy::Manifest);
        run_random_job(&*list_fs, "swift2d", CommitAlgorithm::V1, tasks, &attempts, true);
        run_random_job(&*man_fs, "swift2d", CommitAlgorithm::V1, tasks, &attempts, true);
        let a = readable_parts(&*list_fs, "swift2d");
        let b = readable_parts(&*man_fs, "swift2d");
        assert_eq!(a, b);
    });
}
