//! Backend conformance: one shared suite asserting the `Backend` trait
//! contract (put/get/ranged-get/head/list-pagination/delete/multipart/
//! ETag round-trip), instantiated against every backend via a macro —
//! including `HttpBackend` speaking to an in-process gateway over a real
//! socket — plus fs-only persistence checks, hostile-key round-trips
//! over the wire, and the front-end invariance criterion: the same
//! workload issues the same REST ops (and virtual runtimes, and fault
//! traces) on every backend.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use stocator::gateway::{
    ChaosConfig, GatewayConfig, GatewayHandle, GatewayMode, GatewayServer, HttpBackend,
};
use stocator::harness::{run_cell, Scenario, Sizing, Workload};
use stocator::objectstore::backend::{Backend, BackendError, LocalFsBackend, ShardedMemBackend};
use stocator::objectstore::{BackendKind, Metadata, Object};
use stocator::simclock::SimInstant;

fn unique_root(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "stocator-conformance-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// A backend under test, with optional on-disk state removed on drop
/// (including on panic, so failed runs don't litter the temp dir) and,
/// for the http fixtures, the in-process gateway kept alive for the
/// backend's lifetime. Field order matters: the client (`backend`)
/// drops before `gateway`, closing its pooled connections before the
/// accept loop joins.
struct Fixture {
    backend: Box<dyn Backend>,
    cleanup: Option<PathBuf>,
    gateway: Option<GatewayHandle>,
}

impl Fixture {
    fn backend(&self) -> &dyn Backend {
        &*self.backend
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        if let Some(root) = &self.cleanup {
            let _ = std::fs::remove_dir_all(root);
        }
    }
}

fn mem_fixture(shards: usize) -> Fixture {
    Fixture {
        backend: Box::new(ShardedMemBackend::new(shards)),
        cleanup: None,
        gateway: None,
    }
}

fn fs_fixture() -> Fixture {
    let root = unique_root("fx");
    Fixture {
        backend: Box::new(LocalFsBackend::open(&root).unwrap()),
        cleanup: Some(root),
        gateway: None,
    }
}

/// The tentpole fixture: every conformance check runs through
/// `HttpBackend` → a real TCP socket → an in-process `GatewayServer` on
/// an ephemeral port → a sharded in-memory backend.
fn http_fixture() -> Fixture {
    let inner = Arc::new(ShardedMemBackend::new(4));
    let server = GatewayServer::bind("127.0.0.1:0", inner).expect("bind ephemeral gateway");
    let handle = server.spawn();
    let client = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect gateway");
    Fixture {
        backend: Box::new(client),
        cleanup: None,
        gateway: Some(handle),
    }
}

/// The same wire path served by the non-blocking reactor core instead
/// of thread-per-connection: every conformance check must pass
/// byte-identically against either core.
fn reactor_fixture() -> Fixture {
    let inner = Arc::new(ShardedMemBackend::new(4));
    let config = GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() };
    let server =
        GatewayServer::bind_with("127.0.0.1:0", inner, config).expect("bind reactor gateway");
    let handle = server.spawn();
    let client = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect gateway");
    Fixture {
        backend: Box::new(client),
        cleanup: None,
        gateway: Some(handle),
    }
}

/// The wire path through a gateway whose chaos plane is configured but
/// fully disarmed (every probability 0) — the chaos-disabled invariance
/// fixture: with the replay cache live and every chaos hook wired in,
/// an all-zero spec must be byte-identical to no chaos at all.
fn chaos_zero_fixture() -> Fixture {
    let inner = Arc::new(ShardedMemBackend::new(4));
    let config = GatewayConfig {
        mode: GatewayMode::Reactor,
        chaos: ChaosConfig::parse("kill-response@p=0,truncate@p=0,stall@p=0,reset@p=0")
            .expect("all-zero chaos spec"),
        ..GatewayConfig::default()
    };
    let server =
        GatewayServer::bind_with("127.0.0.1:0", inner, config).expect("bind chaos-zero gateway");
    let handle = server.spawn();
    let client = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect gateway");
    Fixture {
        backend: Box::new(client),
        cleanup: None,
        gateway: Some(handle),
    }
}

/// An http fixture over a *persistent* inner backend (gateway → fs),
/// for the hostile-key wire tests.
fn http_over_fs_fixture() -> Fixture {
    let root = unique_root("http-fs");
    let inner = Arc::new(LocalFsBackend::open(&root).unwrap());
    let server = GatewayServer::bind("127.0.0.1:0", inner).expect("bind ephemeral gateway");
    let handle = server.spawn();
    let client = HttpBackend::connect(&handle.addr().to_string(), None).expect("connect gateway");
    Fixture {
        backend: Box::new(client),
        cleanup: Some(root),
        gateway: Some(handle),
    }
}

fn obj(data: &[u8], t: u64) -> Object {
    Object::new(data.to_vec(), Metadata::new(), SimInstant(t))
}

// ---- the shared checks ----------------------------------------------------

fn check_container_ops(b: &dyn Backend) {
    assert!(!b.container_exists("res"));
    assert!(matches!(
        b.put("res", "k", obj(b"x", 0)),
        Err(BackendError::NoSuchContainer(_))
    ));
    assert!(matches!(
        b.get("res", "k"),
        Err(BackendError::NoSuchContainer(_))
    ));
    assert!(matches!(
        b.list_page("res", "", None, 10),
        Err(BackendError::NoSuchContainer(_))
    ));
    b.create_container("res").unwrap();
    assert!(b.container_exists("res"));
    assert!(matches!(
        b.create_container("res"),
        Err(BackendError::ContainerAlreadyExists(_))
    ));
    assert_eq!(b.live_count("res"), 0);
}

fn check_put_get_head_etag_roundtrip(b: &dyn Backend) {
    b.create_container("res").unwrap();
    let mut md = Metadata::new();
    md.insert("X-Stocator-Origin".into(), "stocator 1.0/a+b".into());
    let stored = Object::new(b"payload".to_vec(), md, SimInstant(7));
    let etag = stored.etag;
    assert!(!b.put("res", "d/part-0001", stored).unwrap());
    let got = b.get("res", "d/part-0001").unwrap();
    assert_eq!(&**got.data, b"payload");
    assert_eq!(got.etag, etag);
    assert_eq!(got.created_at, SimInstant(7));
    assert_eq!(
        got.metadata.get("X-Stocator-Origin").map(String::as_str),
        Some("stocator 1.0/a+b")
    );
    let head = b.head("res", "d/part-0001").unwrap();
    assert_eq!(head.size, 7);
    assert_eq!(head.etag, etag);
    assert_eq!(head.created_at, SimInstant(7));
    assert_eq!(
        head.metadata.get("X-Stocator-Origin").map(String::as_str),
        Some("stocator 1.0/a+b")
    );
    assert!(matches!(
        b.get("res", "d/part-0002"),
        Err(BackendError::NoSuchKey(_))
    ));
    assert!(matches!(
        b.head("res", "nope"),
        Err(BackendError::NoSuchKey(_))
    ));
}

fn check_last_writer_wins(b: &dyn Backend) {
    b.create_container("res").unwrap();
    assert!(!b.put("res", "k", obj(b"first", 0)).unwrap());
    assert!(b.put("res", "k", obj(b"2nd", 1)).unwrap());
    let got = b.get("res", "k").unwrap();
    assert_eq!(&**got.data, b"2nd");
    assert_eq!(got.etag, Object::new(b"2nd".to_vec(), Metadata::new(), SimInstant(9)).etag);
    assert_eq!(b.live_count("res"), 1);
    assert_eq!(b.live_bytes("res"), 3);
}

fn check_delete(b: &dyn Backend) {
    b.create_container("res").unwrap();
    b.put("res", "k", obj(b"data", 0)).unwrap();
    let stat = b.delete("res", "k").unwrap();
    assert_eq!(stat.size, 4);
    assert_eq!(stat.etag, obj(b"data", 5).etag);
    assert!(matches!(b.get("res", "k"), Err(BackendError::NoSuchKey(_))));
    assert!(matches!(
        b.delete("res", "k"),
        Err(BackendError::NoSuchKey(k)) if k == "res/k"
    ));
    assert_eq!(b.live_count("res"), 0);
    assert_eq!(b.live_bytes("res"), 0);
}

fn check_get_range_contract(b: &dyn Backend) {
    b.create_container("res").unwrap();
    let payload: Vec<u8> = (0u8..100).collect();
    b.put("res", "d/obj", obj(&payload, 3)).unwrap();
    // Mid-object slice, with the FULL object's stat (Content-Range total).
    let (bytes, stat) = b.get_range("res", "d/obj", 10, 5).unwrap();
    assert_eq!(bytes, &payload[10..15]);
    assert_eq!(stat.size, 100, "stat must carry the full size");
    assert_eq!(stat.etag, obj(&payload, 9).etag, "stat carries the object etag");
    // Zero-length range: valid, empty.
    let (bytes, _) = b.get_range("res", "d/obj", 10, 0).unwrap();
    assert!(bytes.is_empty());
    // Exact-EOF range.
    let (bytes, _) = b.get_range("res", "d/obj", 90, 10).unwrap();
    assert_eq!(bytes, &payload[90..100]);
    // Over-long ranges clamp to EOF (HTTP semantics).
    let (bytes, _) = b.get_range("res", "d/obj", 90, 1_000).unwrap();
    assert_eq!(bytes, &payload[90..100]);
    // offset == size: valid, empty, whatever the length.
    let (bytes, _) = b.get_range("res", "d/obj", 100, 7).unwrap();
    assert!(bytes.is_empty());
    // offset strictly past EOF: InvalidRange, not Io, not NoSuchKey.
    assert!(matches!(
        b.get_range("res", "d/obj", 101, 1),
        Err(BackendError::InvalidRange(_))
    ));
    // Missing key stays NoSuchKey even with a bad range.
    assert!(matches!(
        b.get_range("res", "missing", 9_999, 1),
        Err(BackendError::NoSuchKey(_))
    ));
    // Whole object via one range.
    let (bytes, _) = b.get_range("res", "d/obj", 0, 100).unwrap();
    assert_eq!(bytes, payload);
}

fn check_list_pagination(b: &dyn Backend) {
    b.create_container("res").unwrap();
    let mut expect = Vec::new();
    for i in 0..25 {
        let name = format!("p/part-{i:03}");
        b.put("res", &name, obj(&[i as u8; 3], 0)).unwrap();
        expect.push(name);
    }
    b.put("res", "q/other", obj(b"x", 0)).unwrap();
    // Page through prefix "p/" ten entries at a time.
    let mut got = Vec::new();
    let mut start_after: Option<String> = None;
    let mut pages = 0;
    loop {
        let page = b
            .list_page("res", "p/", start_after.as_deref(), 10)
            .unwrap();
        assert!(page.entries.len() <= 10);
        for e in &page.entries {
            assert!(e.name.starts_with("p/"));
            assert_eq!(e.size, 3);
        }
        got.extend(page.entries.iter().map(|e| e.name.clone()));
        pages += 1;
        match page.next {
            Some(n) => {
                assert_eq!(Some(&n), got.last(), "next token is the last key returned");
                start_after = Some(n);
            }
            None => break,
        }
        assert!(pages < 10, "pagination failed to terminate");
    }
    assert_eq!(got, expect, "sorted, complete, no duplicates");
    assert!(pages >= 3, "25 entries at page size 10 need >= 3 pages");
    // start_after past the end yields an empty final page.
    let tail = b.list_page("res", "p/", Some("p/part-999"), 10).unwrap();
    assert!(tail.entries.is_empty() && tail.next.is_none());
}

fn check_multipart_lifecycle(b: &dyn Backend) {
    b.create_container("res").unwrap();
    let id = b.initiate_multipart("res", "big", Metadata::new()).unwrap();
    assert_eq!(b.multipart_in_flight(), 1);
    b.upload_part(id, 2, b"world".to_vec()).unwrap();
    b.upload_part(id, 1, b"hello ".to_vec()).unwrap();
    let asm = b.complete_multipart(id, 0).unwrap();
    assert_eq!(asm.container, "res");
    assert_eq!(asm.key, "big");
    assert_eq!(asm.data, b"hello world");
    assert_eq!(b.multipart_in_flight(), 0);
    // The id is consumed.
    assert!(matches!(
        b.complete_multipart(id, 0),
        Err(BackendError::NoSuchUpload(_))
    ));
    assert!(matches!(
        b.upload_part(id, 3, vec![]),
        Err(BackendError::NoSuchUpload(_))
    ));
    // Abort path.
    let id2 = b.initiate_multipart("res", "x", Metadata::new()).unwrap();
    b.upload_part(id2, 1, b"junk".to_vec()).unwrap();
    b.abort_multipart(id2).unwrap();
    assert_eq!(b.multipart_in_flight(), 0);
    assert!(matches!(
        b.abort_multipart(id2),
        Err(BackendError::NoSuchUpload(_))
    ));
    // Initiating against a missing container fails.
    assert!(matches!(
        b.initiate_multipart("nope", "k", Metadata::new()),
        Err(BackendError::NoSuchContainer(_))
    ));
}

fn check_multipart_min_part_size(b: &dyn Backend) {
    b.create_container("res").unwrap();
    let id = b.initiate_multipart("res", "k", Metadata::new()).unwrap();
    b.upload_part(id, 1, vec![0u8; 3]).unwrap(); // non-final part too small
    b.upload_part(id, 2, vec![0u8; 10]).unwrap();
    assert!(matches!(
        b.complete_multipart(id, 10),
        Err(BackendError::InvalidRequest(_))
    ));
    // A failed complete still consumes the upload (S3 semantics).
    assert_eq!(b.multipart_in_flight(), 0);
    assert!(matches!(
        b.complete_multipart(id, 10),
        Err(BackendError::NoSuchUpload(_))
    ));
}

// ---- instantiate the suite per backend ------------------------------------

macro_rules! conformance_suite {
    ($modname:ident, $mk:expr) => {
        mod $modname {
            use super::*;

            fn run(check: fn(&dyn Backend)) {
                let fixture = $mk;
                check(fixture.backend());
            }

            #[test]
            fn container_ops() {
                run(check_container_ops);
            }

            #[test]
            fn put_get_head_etag_roundtrip() {
                run(check_put_get_head_etag_roundtrip);
            }

            #[test]
            fn last_writer_wins() {
                run(check_last_writer_wins);
            }

            #[test]
            fn delete_returns_final_stat() {
                run(check_delete);
            }

            #[test]
            fn get_range_contract() {
                run(check_get_range_contract);
            }

            #[test]
            fn list_pagination() {
                run(check_list_pagination);
            }

            #[test]
            fn multipart_lifecycle() {
                run(check_multipart_lifecycle);
            }

            #[test]
            fn multipart_min_part_size() {
                run(check_multipart_min_part_size);
            }
        }
    };
}

conformance_suite!(single_mem, mem_fixture(1));
conformance_suite!(sharded_mem, mem_fixture(16));
conformance_suite!(local_fs, fs_fixture());
conformance_suite!(http_gateway, http_fixture());
conformance_suite!(http_reactor, reactor_fixture());
conformance_suite!(http_chaos_zero, chaos_zero_fixture());

// ---- cross-backend and fs-specific checks ---------------------------------

#[test]
fn etags_agree_across_backends() {
    let mem = mem_fixture(16);
    let fsx = fs_fixture();
    for f in [&mem, &fsx] {
        f.backend().create_container("res").unwrap();
        f.backend().put("res", "k", obj(b"same bytes", 3)).unwrap();
    }
    let a = mem.backend().head("res", "k").unwrap();
    let b = fsx.backend().head("res", "k").unwrap();
    assert_eq!(a.etag, b.etag);
    assert_eq!(a.size, b.size);
}

#[test]
fn fs_state_survives_reopen() {
    let root = unique_root("persist");
    {
        let b = LocalFsBackend::open(&root).unwrap();
        b.create_container("res").unwrap();
        let mut md = Metadata::new();
        md.insert("origin".into(), "first process".into());
        b.put(
            "res",
            "d/part-0",
            Object::new(b"durable".to_vec(), md, SimInstant(11)),
        )
        .unwrap();
        let id = b.initiate_multipart("res", "pending", Metadata::new()).unwrap();
        b.upload_part(id, 1, b"half".to_vec()).unwrap();
    } // "process exit"
    let b = LocalFsBackend::open(&root).unwrap();
    assert!(b.container_exists("res"));
    let got = b.get("res", "d/part-0").unwrap();
    assert_eq!(&**got.data, b"durable");
    assert_eq!(got.created_at, SimInstant(11));
    assert_eq!(got.etag, obj(b"durable", 0).etag);
    assert_eq!(got.metadata.get("origin").map(String::as_str), Some("first process"));
    // The in-flight upload survived, and fresh ids do not collide with it.
    assert_eq!(b.multipart_in_flight(), 1);
    let id2 = b.initiate_multipart("res", "another", Metadata::new()).unwrap();
    b.upload_part(id2, 1, b"part".to_vec()).unwrap();
    let asm = b.complete_multipart(id2, 0).unwrap();
    assert_eq!(asm.key, "another");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn fs_keys_with_hostile_names_roundtrip() {
    let f = fs_fixture();
    let b = f.backend();
    b.create_container("res").unwrap();
    for key in [
        "a/b/c/part-0",
        "_temporary/0/_temporary/attempt_x/part-1",
        ".hidden",
        "sp ace%and%percent",
        "_SUCCESS",
    ] {
        b.put("res", key, obj(b"v", 0)).unwrap();
    }
    let page = b.list_page("res", "", None, 100).unwrap();
    let mut names: Vec<&str> = page.entries.iter().map(|e| e.name.as_str()).collect();
    names.sort_unstable();
    assert_eq!(
        names,
        vec![
            ".hidden",
            "_SUCCESS",
            "_temporary/0/_temporary/attempt_x/part-1",
            "a/b/c/part-0",
            "sp ace%and%percent",
        ]
    );
    assert!(b.get("res", ".hidden").is_ok());
}

/// Hostile key names over the wire: the conformance suite's hostile
/// cases (plus unicode, query metacharacters and `+`) must round-trip
/// through `HttpBackend` → percent-encoded URL → gateway → every kind
/// of inner backend — data, listings, ranged reads, HEAD and DELETE.
#[test]
fn hostile_keys_roundtrip_over_the_wire_on_every_inner_backend() {
    const HOSTILE: [&str; 8] = [
        "a/b/c/part-0",
        "_temporary/0/_temporary/attempt_x/part-1",
        ".hidden",
        "sp ace%and%percent",
        "_SUCCESS",
        "uni-cöde-日本-ключ",
        "query?amp&eq=1#frag",
        "plus+sign~tilde,comma",
    ];
    for fixture in [http_fixture(), http_over_fs_fixture()] {
        let b = fixture.backend();
        b.create_container("res").unwrap();
        for (i, key) in HOSTILE.iter().enumerate() {
            let body = format!("payload-{i}");
            b.put("res", key, obj(body.as_bytes(), i as u64)).unwrap();
            // Whole-object read carries data + stat back through the
            // percent-decoded response.
            let got = b.get("res", key).unwrap();
            assert_eq!(&**got.data, body.as_bytes(), "key {key:?}");
            assert_eq!(got.created_at, SimInstant(i as u64), "key {key:?}");
            // Ranged read on the same hostile URL.
            let (bytes, stat) = b.get_range("res", key, 0, 7).unwrap();
            assert_eq!(bytes, b"payload", "key {key:?}");
            assert_eq!(stat.size, body.len() as u64, "key {key:?}");
            // HEAD agrees.
            assert_eq!(b.head("res", key).unwrap().etag, got.etag, "key {key:?}");
        }
        // Listings come back decoded, sorted, complete.
        let page = b.list_page("res", "", None, 100).unwrap();
        let names: Vec<&str> = page.entries.iter().map(|e| e.name.as_str()).collect();
        let mut expect: Vec<&str> = HOSTILE.to_vec();
        expect.sort_unstable();
        assert_eq!(names, expect);
        // Prefix listings work on hostile prefixes too.
        let page = b.list_page("res", "sp ace%", None, 100).unwrap();
        assert_eq!(page.entries.len(), 1);
        assert_eq!(page.entries[0].name, "sp ace%and%percent");
        // Delete round-trips and 404s stay exact.
        for key in HOSTILE {
            b.delete("res", key).unwrap();
            assert!(
                matches!(b.get("res", key), Err(BackendError::NoSuchKey(k)) if k == format!("res/{key}")),
                "key {key:?}"
            );
        }
        assert_eq!(b.live_count("res"), 0);
    }
}

/// Reusing one fs root across repetitions and invocations must not
/// collide: the harness gives every environment a unique subdirectory.
#[test]
fn fs_root_is_reusable_across_runs() {
    let root = unique_root("reuse");
    let mut sizing = Sizing::small();
    sizing.backend = BackendKind::LocalFs(Some(root.clone()));
    let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 2);
    assert!(cell.valid, "{}", cell.validation);
    // A "second process" against the same DIR works too.
    let again = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    assert!(again.valid, "{}", again.validation);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance criterion: the front end's REST op accounting is
/// backend-invariant — a full Stocator Teragen cell issues identical op
/// counts and bytes on every backend, *including over a real socket*
/// through an in-process gateway. This is the golden-opcount scenario
/// for the HTTP path: REST op counts and virtual runtimes must be
/// byte-identical to `mem`.
#[test]
fn front_end_op_counts_are_backend_invariant() {
    let run_with = |backend: BackendKind| {
        let mut sizing = Sizing::small();
        sizing.backend = backend;
        let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
        (cell.ops, cell.runtime_mean_s)
    };
    let (mem_ops, mem_rt) = run_with(BackendKind::Mem);
    let (sharded_ops, sharded_rt) = run_with(BackendKind::Sharded(16));
    let fs_root = unique_root("invariance");
    let (fs_ops, fs_rt) = run_with(BackendKind::LocalFs(Some(fs_root.clone())));
    let _ = std::fs::remove_dir_all(&fs_root);
    let gateway = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
        .expect("bind gateway")
        .spawn();
    let (http_ops, http_rt) = run_with(BackendKind::Http {
        addr: gateway.addr().to_string(),
        ns: None,
    });
    // The reactor core serves the same wire protocol from one
    // non-blocking thread: same golden op counts.
    let reactor = GatewayServer::bind_with(
        "127.0.0.1:0",
        Arc::new(ShardedMemBackend::new(4)),
        GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() },
    )
    .expect("bind reactor gateway")
    .spawn();
    let (reactor_ops, reactor_rt) = run_with(BackendKind::Http {
        addr: reactor.addr().to_string(),
        ns: None,
    });
    assert_eq!(mem_ops, sharded_ops);
    assert_eq!(mem_ops, fs_ops);
    assert_eq!(mem_ops, http_ops, "REST ops over the wire must match mem exactly");
    assert_eq!(mem_ops, reactor_ops, "REST ops through the reactor core must match mem exactly");
    // Virtual-clock runtime is also invariant (jitter is 0 in small sizing).
    assert_eq!(mem_rt, sharded_rt);
    assert_eq!(mem_rt, fs_rt);
    assert_eq!(mem_rt, http_rt, "virtual runtime over the wire must match mem exactly");
    assert_eq!(mem_rt, reactor_rt, "virtual runtime through the reactor must match mem exactly");
}

/// The headline invariance criterion for the production plane: a
/// *rate-limited* reactor gateway emits real `429 Too Many Requests`
/// on the wire, `HttpBackend` sleeps out each `Retry-After` and
/// re-sends, and the workload's REST op accounting comes out
/// byte-identical to an in-memory run — backpressure is invisible
/// above the `Backend` trait.
#[test]
fn rate_limited_reactor_preserves_golden_op_counts() {
    let run_with = |backend: BackendKind| {
        let mut sizing = Sizing::small();
        sizing.backend = backend;
        let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
        (cell.ops, cell.runtime_mean_s)
    };
    let (mem_ops, mem_rt) = run_with(BackendKind::Mem);
    // A rate low enough that the workload's request stream provably
    // outruns the bucket, high enough that sleeping out the refills
    // stays test-friendly.
    let limited = GatewayServer::bind_with(
        "127.0.0.1:0",
        Arc::new(ShardedMemBackend::new(4)),
        GatewayConfig {
            mode: GatewayMode::Reactor,
            rate_limit: 400.0,
            burst: 8,
            ..GatewayConfig::default()
        },
    )
    .expect("bind rate-limited reactor")
    .spawn();
    let (ops, rt) = run_with(BackendKind::Http {
        addr: limited.addr().to_string(),
        ns: None,
    });
    assert!(
        limited.throttled_429s() >= 1,
        "the limiter must actually have rejected requests on the wire"
    );
    assert_eq!(mem_ops, ops, "op counts must survive real 429 backpressure unchanged");
    assert_eq!(mem_rt, rt, "virtual runtime must survive real 429 backpressure unchanged");
}

/// Chaos-disabled invariance: a gateway with the chaos plane wired in
/// but every probability at zero — and the request-id replay cache
/// always on — must reproduce the in-memory golden op counts and
/// virtual runtime exactly, on BOTH server cores. The robustness
/// machinery must cost nothing (and change nothing) when disarmed.
#[test]
fn chaos_disabled_gateway_preserves_golden_op_counts_on_both_cores() {
    let run_with = |backend: BackendKind| {
        let mut sizing = Sizing::small();
        sizing.backend = backend;
        let cell = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        assert!(cell.valid, "{}", cell.validation);
        (cell.ops, cell.runtime_mean_s)
    };
    let (mem_ops, mem_rt) = run_with(BackendKind::Mem);
    for mode in [GatewayMode::Reactor, GatewayMode::Threaded] {
        let gw = GatewayServer::bind_with(
            "127.0.0.1:0",
            Arc::new(ShardedMemBackend::new(4)),
            GatewayConfig {
                mode,
                chaos: ChaosConfig::parse("kill-response@p=0,truncate@p=0,stall@p=0,reset@p=0")
                    .unwrap(),
                ..GatewayConfig::default()
            },
        )
        .expect("bind chaos-zero gateway")
        .spawn();
        let (ops, rt) = run_with(BackendKind::Http {
            addr: gw.addr().to_string(),
            ns: None,
        });
        assert_eq!(
            gw.chaos_injected(),
            0,
            "an all-zero chaos spec must never fire ({} core)",
            mode.name()
        );
        assert_eq!(mem_ops, ops, "op counts must be chaos-spec-invariant ({} core)", mode.name());
        assert_eq!(mem_rt, rt, "runtime must be chaos-spec-invariant ({} core)", mode.name());
    }
}

/// Two cells against ONE long-lived gateway must not collide: the
/// harness namespaces each environment's containers (the http analogue
/// of the fs backend's per-env subdirectory), and results stay
/// identical run over run.
#[test]
fn repeated_cells_share_one_gateway_without_collisions() {
    let gateway = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
        .expect("bind gateway")
        .spawn();
    let mut sizing = Sizing::small();
    sizing.backend = BackendKind::Http {
        addr: gateway.addr().to_string(),
        ns: None,
    };
    let first = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 2);
    assert!(first.valid, "{}", first.validation);
    let again = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    assert!(again.valid, "{}", again.validation);
    assert_eq!(first.ops, again.ops);
}

/// Regression (readahead × range contract): a readahead *fill* is
/// `max(requested, window)` bytes, so near end-of-file it routinely asks
/// for more than the object holds. A fill that starts before EOF must be
/// clamped to partial content — never surfaced as `InvalidRange` — on
/// every backend (the fs backend does a real seek+read); only a read
/// starting strictly past EOF is the 416. Exercised through the full
/// stack: connector → ReadaheadStream → ObjectStore → Backend.
#[test]
fn readahead_fill_clamps_at_eof_on_every_backend() {
    use stocator::connectors::Stocator;
    use stocator::fs::{FileSystem, FsError, FsInputStream, OpCtx, Path};
    use stocator::objectstore::{ObjectStore, StoreConfig};

    struct Reap(Option<PathBuf>);
    impl Drop for Reap {
        fn drop(&mut self) {
            if let Some(p) = &self.0 {
                let _ = std::fs::remove_dir_all(p);
            }
        }
    }

    let fs_root = unique_root("readahead-eof");
    for kind in [
        BackendKind::Mem,
        BackendKind::Sharded(4),
        BackendKind::LocalFs(Some(fs_root.clone())),
    ] {
        let _reap = Reap(match &kind {
            BackendKind::LocalFs(Some(p)) => Some(p.clone()),
            _ => None,
        });
        let store = ObjectStore::new(StoreConfig {
            backend: kind.clone(),
            readahead: 64,
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut c = OpCtx::new(SimInstant::EPOCH);
        let path = Path::parse("swift2d://res/in/part-0").unwrap();
        fs.write_all(&path, (0u8..100).collect(), true, &mut c).unwrap();
        let mut input = fs.open(&path, &mut c).unwrap();
        // The fill fetches 64 bytes from offset 90 — 54 past EOF: partial
        // content, not a 416.
        let tail = input.read_range(90, 8, &mut c).unwrap();
        assert_eq!(tail, (90u8..98).collect::<Vec<u8>>(), "backend {kind:?}");
        // A read spanning EOF clamps too (served from the EOF-touching
        // window without another fill).
        let spill = input.read_range(95, 20, &mut c).unwrap();
        assert_eq!(spill, (95u8..100).collect::<Vec<u8>>(), "backend {kind:?}");
        // Exactly at EOF: valid and empty. Strictly past: the 416,
        // surfaced uniformly as FsError::InvalidRange.
        assert!(input.read_range(100, 1, &mut c).unwrap().is_empty());
        assert!(
            matches!(input.read_range(101, 1, &mut c), Err(FsError::InvalidRange(_))),
            "backend {kind:?}"
        );
        // And a fresh stream whose FIRST fill starts past EOF also 416s.
        let mut fresh = fs.open(&path, &mut c).unwrap();
        assert!(matches!(
            fresh.read_range(200, 4, &mut c),
            Err(FsError::InvalidRange(_))
        ));
    }
}

#[test]
fn fault_injection_is_backend_invariant() {
    use stocator::connectors::Stocator;
    use stocator::fs::{FileSystem, OpCtx, Path};
    use stocator::objectstore::{FaultOp, FaultSpec, ObjectStore, RetryPolicy, StoreConfig};

    // The fault plane lives in the store FRONT END, so the same fault
    // schedule over the same op sequence must fire at the same op on
    // every backend: identical retry traces, identical op/byte
    // counters, identical surviving objects.
    struct Reap(Option<PathBuf>);
    impl Drop for Reap {
        fn drop(&mut self) {
            if let Some(p) = &self.0 {
                let _ = std::fs::remove_dir_all(p);
            }
        }
    }

    let fs_root = unique_root("faults");
    let gateway = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
        .expect("bind gateway")
        .spawn();
    let reactor = GatewayServer::bind_with(
        "127.0.0.1:0",
        Arc::new(ShardedMemBackend::new(4)),
        GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() },
    )
    .expect("bind reactor gateway")
    .spawn();
    let mut snapshots: Vec<(String, Vec<String>, u64, u64, Vec<String>)> = Vec::new();
    for kind in [
        BackendKind::Mem,
        BackendKind::Sharded(4),
        BackendKind::LocalFs(Some(fs_root.clone())),
        BackendKind::Http {
            addr: gateway.addr().to_string(),
            ns: Some("faults-inv".to_string()),
        },
        BackendKind::Http {
            addr: reactor.addr().to_string(),
            ns: Some("faults-inv-reactor".to_string()),
        },
    ] {
        let _reap = Reap(match &kind {
            BackendKind::LocalFs(Some(p)) => Some(p.clone()),
            _ => None,
        });
        let store = ObjectStore::new(StoreConfig {
            backend: kind.clone(),
            faults: FaultSpec::one(FaultOp::Put, "d/part", 1),
            retry: RetryPolicy::with_retries(1),
            ..StoreConfig::instant_strong()
        });
        store.create_container("res", SimInstant::EPOCH).0.unwrap();
        let fs = Stocator::with_defaults(store.clone());
        let mut c = OpCtx::traced(SimInstant::EPOCH);
        let temp = Path::parse(
            "swift2d://res/d/_temporary/0/_temporary/attempt_201512062056_0000_m_000000_0/part-0",
        )
        .unwrap();
        fs.write_all(&temp, (0u8..50).collect(), true, &mut c).unwrap();
        // And one faulted read for the GET side of the plane.
        let armed = FaultSpec::one(FaultOp::Get, "d/part", 1);
        store.arm_faults(&armed);
        let final_key = "d/part-0_attempt_201512062056_0000_m_000000_0";
        let data = fs
            .read_all(&Path::parse(&format!("swift2d://res/{final_key}")).unwrap(), &mut c)
            .unwrap();
        assert_eq!(&*data, &(0u8..50).collect::<Vec<u8>>()[..], "backend {kind:?}");
        let counts = store.counters();
        snapshots.push((
            format!("{kind:?}"),
            c.take_trace(),
            counts.total(),
            counts.bytes_written,
            store.debug_names("res", "d/"),
        ));
    }
    let (_, trace0, total0, bytes0, names0) = &snapshots[0];
    assert!(
        trace0.iter().any(|l| l.contains("(503 transient)")),
        "the fault must actually fire: {trace0:?}"
    );
    for (kind, trace, total, bytes, names) in &snapshots[1..] {
        assert_eq!(trace, trace0, "trace diverged on {kind}");
        assert_eq!(total, total0, "op total diverged on {kind}");
        assert_eq!(bytes, bytes0, "wire bytes diverged on {kind}");
        assert_eq!(names, names0, "surviving objects diverged on {kind}");
    }
}
