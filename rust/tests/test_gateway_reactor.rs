//! Failure-surface and scalability tests for the reactor gateway core:
//! keep-alive pipelining on one socket, connection-cap shedding (503
//! over-capacity) with recovery, real token-bucket 429s absorbed by
//! `HttpBackend`, bearer auth (401/403), malformed-request survival,
//! slow-loris 408 (while idle keep-alives live on), graceful drain, and
//! the `--open-conns` idle-connection plane of `stress`.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use stocator::gateway::http::{read_response, write_request, Headers, Response};
use stocator::gateway::{GatewayConfig, GatewayHandle, GatewayMode, GatewayServer, HttpBackend};
use stocator::loadgen::{run_stress, StressConfig};
use stocator::objectstore::backend::{Backend, BackendError, ShardedMemBackend};
use stocator::objectstore::{Metadata, Object};
use stocator::simclock::SimInstant;

/// Spawn a reactor-core gateway over a fresh sharded store with the
/// given knobs applied on top of the defaults.
fn reactor(tweak: impl FnOnce(&mut GatewayConfig)) -> GatewayHandle {
    let mut config = GatewayConfig { mode: GatewayMode::Reactor, ..GatewayConfig::default() };
    tweak(&mut config);
    GatewayServer::bind_with("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)), config)
        .expect("bind reactor gateway")
        .spawn()
}

/// One raw round-trip on a dedicated connection.
fn raw_roundtrip(addr: &str, method: &str, target: &str, headers: &Headers) -> Response {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    write_request(&mut write_half, method, target, headers, b"").expect("write");
    read_response(&mut BufReader::new(stream)).expect("response")
}

fn obj(data: &[u8]) -> Object {
    Object::new(data.to_vec(), Metadata::new(), SimInstant(0))
}

#[test]
fn keep_alive_pipelining_serves_requests_in_order_on_one_socket() {
    let handle = reactor(|_| {});
    let addr = handle.addr().to_string();
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    // Three requests written back-to-back before reading anything: the
    // reactor must frame them via the incremental parser and answer
    // strictly in order on the same connection.
    let mut burst = Vec::new();
    write_request(&mut burst, "GET", "/healthz", &Headers::new(), b"").unwrap();
    write_request(&mut burst, "PUT", "/v1/pipelined", &Headers::new(), b"").unwrap();
    write_request(&mut burst, "GET", "/healthz", &Headers::new(), b"").unwrap();
    write_half.write_all(&burst).expect("pipelined write");
    let mut reader = BufReader::new(stream);
    let statuses: Vec<u16> = (0..3)
        .map(|_| read_response(&mut reader).expect("response").status)
        .collect();
    assert_eq!(statuses, vec![200, 201, 200]);
    // The connection is still a live keep-alive afterwards.
    write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
    assert_eq!(read_response(&mut reader).unwrap().status, 200);
}

#[test]
fn connection_cap_sheds_503_and_recovers_when_a_slot_frees() {
    let handle = reactor(|c| c.max_conns = 2);
    let addr = handle.addr().to_string();
    // Fill both slots, proving each connection is registered (one
    // served round-trip) before holding it open.
    let mut held = Vec::new();
    for _ in 0..2 {
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut write_half = stream.try_clone().expect("clone");
        write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
        let mut reader = BufReader::new(stream);
        assert_eq!(read_response(&mut reader).unwrap().status, 200);
        held.push(reader.into_inner());
    }
    // The third connection is shed at accept: an immediate 503 with a
    // parseable Retry-After, before any request byte is read.
    let over = TcpStream::connect(&addr).expect("connect past cap");
    let resp = read_response(&mut BufReader::new(over)).expect("shed response");
    assert_eq!(resp.status, 503);
    assert_eq!(resp.headers.get("x-error-kind"), Some("over-capacity"));
    let after: f64 = resp
        .headers
        .get("retry-after")
        .expect("503 carries Retry-After")
        .parse()
        .expect("Retry-After parses as f64");
    assert!(after > 0.0);
    assert!(handle.shed_503s() >= 1);
    // Free a slot; the reactor reaps the closed connection on a sweep
    // and new clients get in again.
    drop(held.pop());
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut write_half = stream.try_clone().expect("clone");
        write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
        match read_response(&mut BufReader::new(stream)) {
            Ok(resp) if resp.status == 200 => break,
            _ if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("gateway never recovered after the cap cleared: {other:?}"),
        }
    }
}

#[test]
fn token_bucket_emits_parseable_429s_and_http_backend_recovers() {
    let handle = reactor(|c| {
        c.rate_limit = 500.0;
        c.burst = 4;
    });
    let addr = handle.addr().to_string();
    // Wire-level: hammer one connection until the bucket runs dry; the
    // 429 must carry a positive fractional Retry-After and must NOT
    // close the connection.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut throttled = None;
    for _ in 0..50 {
        write_request(&mut write_half, "HEAD", "/v1/absent", &Headers::new(), b"").unwrap();
        let resp = read_response(&mut reader).expect("response");
        if resp.status == 429 {
            throttled = Some(resp);
            break;
        }
    }
    let throttled = throttled.expect("burst of 50 must outrun a burst-4 bucket");
    let after: f64 = throttled
        .headers
        .get("retry-after")
        .expect("429 carries Retry-After")
        .parse()
        .expect("Retry-After parses as f64");
    assert!(after > 0.0);
    // Same connection still serves after the rejection.
    write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
    assert_eq!(read_response(&mut reader).unwrap().status, 200);
    // Client-level: HttpBackend sleeps out each Retry-After and every
    // operation still succeeds — backpressure is invisible above the
    // Backend trait.
    let b = HttpBackend::connect(&addr, None).expect("connect backend");
    b.create_container("res").unwrap();
    for i in 0..40u8 {
        let key = format!("k/{i}");
        b.put("res", &key, obj(&[i; 32])).unwrap();
        assert_eq!(&**b.get("res", &key).unwrap().data, &[i; 32]);
    }
    assert_eq!(b.live_count("res"), 40);
    assert!(handle.throttled_429s() >= 1, "the limiter never fired");
    assert!(b.throttled_429s() >= 1, "the client never absorbed a 429");
}

#[test]
fn bearer_auth_rejects_missing_and_wrong_tokens_but_admits_the_right_one() {
    let handle = reactor(|c| c.auth_token = Some("open-sesame".into()));
    let addr = handle.addr().to_string();
    // Missing token: 401 with a WWW-Authenticate challenge — and the
    // connection stays usable (screening rejections keep keep-alive).
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    write_request(&mut write_half, "GET", "/v1/c/k", &Headers::new(), b"").unwrap();
    let resp = read_response(&mut reader).unwrap();
    assert_eq!(resp.status, 401);
    assert_eq!(resp.headers.get("www-authenticate"), Some("Bearer"));
    assert_eq!(resp.headers.get("x-error-kind"), Some("unauthorized"));
    // Wrong token on the SAME socket: 403.
    let mut wrong = Headers::new();
    wrong.push("Authorization", "Bearer nope");
    write_request(&mut write_half, "GET", "/v1/c/k", &wrong, b"").unwrap();
    let resp = read_response(&mut reader).unwrap();
    assert_eq!(resp.status, 403);
    assert_eq!(resp.headers.get("x-error-kind"), Some("forbidden"));
    assert!(handle.rejected_auths() >= 2);
    // /healthz needs no token (probes and idle holders stay cheap).
    assert_eq!(raw_roundtrip(&addr, "GET", "/healthz", &Headers::new()).status, 200);
    // A tokenless HttpBackend surfaces the 401 as a descriptive error...
    let anon = HttpBackend::connect(&addr, None).expect("connect");
    match anon.create_container("res") {
        Err(BackendError::Io(msg)) => assert!(msg.contains("401"), "got: {msg}"),
        other => panic!("expected a 401-bearing Io error, got {other:?}"),
    }
    // ...and the authenticated one works end to end.
    let authed = HttpBackend::connect(&addr, None).expect("connect").with_token("open-sesame");
    authed.create_container("res").unwrap();
    authed.put("res", "k", obj(b"payload")).unwrap();
    assert_eq!(&**authed.get("res", "k").unwrap().data, b"payload");
}

#[test]
fn malformed_and_oversized_requests_get_400_without_killing_the_server() {
    let handle = reactor(|_| {});
    let addr = handle.addr().to_string();
    let hostile: [&[u8]; 3] = [
        b"NOT-A-REQUEST\r\n\r\n",
        // Parses as a u64 but exceeds the body cap.
        b"PUT /v1/c/k HTTP/1.1\r\nContent-Length: 99999999999999\r\n\r\n",
        // Blank line where the request line should be.
        b"\r\n",
    ];
    for bytes in hostile {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream.write_all(bytes).expect("write garbage");
        let resp = read_response(&mut BufReader::new(stream)).expect("response");
        assert_eq!(resp.status, 400, "input {:?}", String::from_utf8_lossy(bytes));
    }
    // A truncated request followed by EOF gets the same 400 the
    // blocking parser gives for "EOF inside headers".
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.write_all(b"GET /healthz HTTP/1.1\r\nx-half").expect("write partial");
    stream.shutdown(std::net::Shutdown::Write).expect("half-close");
    let resp = read_response(&mut BufReader::new(stream)).expect("response");
    assert_eq!(resp.status, 400);
    // The server survived all of it.
    assert_eq!(raw_roundtrip(&addr, "GET", "/healthz", &Headers::new()).status, 200);
    let b = HttpBackend::connect(&addr, None).expect("connect");
    b.create_container("res").unwrap();
    b.put("res", "k", obj(b"still fine")).unwrap();
    assert_eq!(&**b.get("res", "k").unwrap().data, b"still fine");
}

#[test]
fn slow_loris_gets_408_while_idle_keepalive_survives_the_timeout() {
    let handle = reactor(|c| c.read_timeout = Duration::from_millis(100));
    let addr = handle.addr().to_string();
    // An idle keep-alive (one served request, then silence) must NOT be
    // reaped, no matter how long it sits.
    let idle = TcpStream::connect(&addr).expect("connect idle");
    let mut idle_write = idle.try_clone().expect("clone");
    let mut idle_reader = BufReader::new(idle);
    write_request(&mut idle_write, "GET", "/healthz", &Headers::new(), b"").unwrap();
    assert_eq!(read_response(&mut idle_reader).unwrap().status, 200);
    // A stalled PARTIAL request is a slow loris: 408 and close.
    let mut loris = TcpStream::connect(&addr).expect("connect loris");
    loris.write_all(b"GET /hea").expect("dribble");
    std::thread::sleep(Duration::from_millis(500));
    let resp = read_response(&mut BufReader::new(loris)).expect("408 response");
    assert_eq!(resp.status, 408);
    assert_eq!(resp.headers.get("x-error-kind"), Some("stalled-request"));
    // The idle connection lived through the same 500ms and still works.
    write_request(&mut idle_write, "GET", "/healthz", &Headers::new(), b"").unwrap();
    assert_eq!(read_response(&mut idle_reader).unwrap().status, 200);
}

#[test]
fn graceful_shutdown_drains_and_closes_idle_connections() {
    let handle = reactor(|c| c.drain_timeout = Duration::from_millis(500));
    let addr = handle.addr().to_string();
    // One idle keep-alive held across the shutdown.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut write_half = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    write_request(&mut write_half, "GET", "/healthz", &Headers::new(), b"").unwrap();
    assert_eq!(read_response(&mut reader).unwrap().status, 200);
    let t0 = Instant::now();
    handle.shutdown();
    // The drain must close idle connections promptly, well inside the
    // drain budget plus join slack.
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown took {:?}",
        t0.elapsed()
    );
    // The held connection was closed server-side: the next read sees
    // EOF, not a response.
    assert!(read_response(&mut reader).is_err());
}

#[test]
fn stress_open_conns_holds_idle_connections_without_violations() {
    let cfg = StressConfig {
        clients: 2,
        shards: 2,
        payload: 512,
        ops_per_client: Some(10),
        matrix: false,
        bench_path: None,
        open_conns: 32,
        core: GatewayMode::Reactor,
        ..StressConfig::default()
    };
    let report = run_stress(&cfg).expect("stress run");
    assert_eq!(report.open_conns, 32);
    assert_eq!(report.open_conns_held, 32, "every idle connection must be held");
    assert_eq!(report.run.violation_count, 0, "{:?}", report.run.violations);
    assert_eq!(report.run.total_ops, 20);
}
