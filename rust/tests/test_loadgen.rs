//! Concurrency-invariant smoke tests for the `stress` load plane: a
//! small hammer run against a real in-process gateway must be correct
//! (zero violations), must never see colliding multipart upload ids
//! across threads, and — in fixed-op-budget mode — must execute a
//! deterministic op mix for a fixed seed.

use stocator::loadgen::{run_stress, OpClass, StressConfig};

fn smoke_config() -> StressConfig {
    StressConfig {
        clients: 4,
        shards: 4,
        payload: 1024,
        seed: 7,
        ops_per_client: Some(40),
        matrix: false,
        bench_path: None,
        ..StressConfig::default()
    }
}

#[test]
fn stress_smoke_is_violation_free_with_unique_upload_ids() {
    let report = run_stress(&smoke_config()).expect("stress run");
    let run = &report.run;
    assert_eq!(
        run.violation_count, 0,
        "correctness violations: {:?}",
        run.violations
    );
    assert_eq!(run.total_ops, 4 * 40);
    // The mixed workload reached the multipart paths, and every upload
    // id issued across all 4 racing workers was distinct.
    assert!(run.upload_ids_issued > 0, "mix never initiated an upload");
    assert_eq!(run.upload_ids_unique, run.upload_ids_issued);
    // Every op class ran and was measured.
    for c in OpClass::ALL {
        let s = run.summary_for(c);
        assert_eq!(s.count, run.executed[c.index()], "{}", c.name());
        if s.count > 0 {
            assert!(s.max_us > 0.0, "{}: zero-latency samples", c.name());
            assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us, "{}", c.name());
        }
    }
    assert!(run.bytes_written > 0);
    assert!(run.elapsed_s > 0.0);
    assert!(run.ops_per_sec > 0.0);
}

#[test]
fn fixed_budget_op_mix_is_deterministic_for_a_seed() {
    let a = run_stress(&smoke_config()).expect("first run");
    let b = run_stress(&smoke_config()).expect("second run");
    // Wall-clock differs run to run; the executed mix must not.
    assert_eq!(a.run.executed, b.run.executed);
    assert_eq!(a.run.bytes_written, b.run.bytes_written);
    assert_eq!(a.run.upload_ids_issued, b.run.upload_ids_issued);
    // A different seed draws a different workload (the op-count vector
    // alone could coincide; the written-byte total — a sum of 160
    // uniform size draws — cannot).
    let c = run_stress(&StressConfig {
        seed: 8,
        ..smoke_config()
    })
    .expect("reseeded run");
    assert!(
        a.run.executed != c.run.executed || a.run.bytes_written != c.run.bytes_written,
        "seeds 7 and 8 produced identical workloads"
    );
}

#[test]
fn bench_json_lands_on_disk_with_percentiles_and_matrix() {
    let dir = std::env::temp_dir().join(format!("stocator-loadgen-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_8.json");
    let cfg = StressConfig {
        clients: 2,
        shards: 2,
        payload: 512,
        seed: 7,
        ops_per_client: Some(16),
        matrix: true,
        bench_path: Some(path.clone()),
        ..StressConfig::default()
    };
    let report = run_stress(&cfg).expect("stress run with matrix");
    assert!(!report.matrix.is_empty());
    assert!(report.matrix.iter().all(|m| m.violation_count == 0));
    // In-process + matrix also runs the server-core head-to-head.
    assert_eq!(report.cores.len(), 2);
    assert!(report.cores.iter().any(|c| c.core == "reactor"));
    assert!(report.cores.iter().any(|c| c.core == "threaded"));
    assert!(report.cores.iter().all(|c| c.violation_count == 0));
    let text = std::fs::read_to_string(&path).expect("BENCH json written");
    for field in [
        "\"bench\"",
        "\"op_classes\"",
        "\"p50_us\"",
        "\"p95_us\"",
        "\"p99_us\"",
        "\"matrix\"",
        "\"ops_per_sec\"",
        "\"multipart_ids\"",
        "\"violations\": 0",
        "\"cores\"",
        "\"throttled_429\"",
        "\"retried_sends\"",
        "\"replayed_responses\"",
        "\"open_conns\"",
    ] {
        assert!(text.contains(field), "missing {field}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
