//! Front-end striping invariance (PR 9): sharding the store front end's
//! visibility overlay and multipart tracker must be *invisible* to every
//! single-threaded result — same op counts, same virtual durations, same
//! fault traces, same visible listings — on every backend, including
//! `HttpBackend` through a real in-process gateway. Plus the lock-free
//! accounting criterion: under 16 real writer threads the atomic op
//! counters lose no updates (exact totals, not floors).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use stocator::gateway::{GatewayHandle, GatewayServer};
use stocator::metrics::OpKind;
use stocator::objectstore::backend::ShardedMemBackend;
use stocator::objectstore::{
    BackendKind, ConsistencyModel, FaultOp, FaultRule, FaultSpec, LatencyModel, Metadata,
    ObjectStore, StoreConfig,
};
use stocator::simclock::{SimDuration, SimInstant};

fn unique_root(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "stocator-striping-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Build a store whose only variable is the front-end stripe count.
/// Eventual consistency (2s lags) keeps the visibility overlay on the
/// hot path; jitter keeps the per-thread RNG streams in play; paper
/// latencies make virtual durations meaningful comparands.
fn striped_store(backend: BackendKind, stripes: usize, faults: FaultSpec) -> Arc<ObjectStore> {
    ObjectStore::new(StoreConfig {
        latency: LatencyModel {
            jitter: 0.1,
            ..LatencyModel::paper_testbed()
        },
        consistency: ConsistencyModel::eventual(),
        min_part_size: 0,
        seed: 9,
        backend,
        stripes,
        faults,
        ..StoreConfig::default()
    })
}

/// A deterministic scripted job crossing every striped structure:
/// timed PUTs and DELETEs (visibility stripes), listings straddling the
/// 2s create/delete lags (stripe-overlay merge), a COPY, a multipart
/// upload completed and one left to the lifecycle sweep (multipart
/// stripes), plus 404 probes. Returns the full observable transcript:
/// one line per op outcome, total virtual time, and the counter
/// snapshot.
fn scripted_job(store: &ObjectStore) -> (Vec<String>, u64, stocator::metrics::OpCounts) {
    const S: u64 = 1_000_000; // 1 virtual second in micros
    let mut trace = Vec::new();
    let mut virt = SimDuration::ZERO;
    macro_rules! run {
        ($line:expr, $d:expr) => {{
            trace.push($line);
            virt += $d;
        }};
    }
    let (r, d) = store.create_container("c", SimInstant::EPOCH);
    run!(format!("create_container {r:?}"), d);
    // 24 timed puts, one every 250ms of virtual time.
    for i in 0..24u64 {
        let key = format!("d/part-{i:02}");
        let data = vec![i as u8; 100 + i as usize];
        let (r, d) = store.put_object("c", &key, data, Metadata::new(), SimInstant(i * S / 4));
        run!(format!("put {key} {r:?}"), d);
    }
    // Reads: hits, a ranged read, and a 404.
    for i in [0u64, 7, 23] {
        let key = format!("d/part-{i:02}");
        let (r, d) = store.get_object("c", &key);
        let line = match r {
            Ok(got) => format!("get {key} ok len={} etag={:016x}", got.data.len(), got.head.etag),
            Err(e) => format!("get {key} {e:?}"),
        };
        run!(line, d);
        let (r, d) = store.head_object("c", &key);
        run!(format!("head {key} {r:?}"), d);
    }
    let (r, d) = store.get_object_range("c", "d/part-05", 10, 40);
    let line = match r {
        Ok(got) => format!("get_range ok len={} full={}", got.data.len(), got.head.size),
        Err(e) => format!("get_range {e:?}"),
    };
    run!(line, d);
    let (r, d) = store.get_object("c", "d/ghost");
    run!(format!("get d/ghost {r:?}"), d);
    // Copy, then delete every third key at t=10s..
    let (r, d) = store.copy_object("c", "d/part-00", "c", "out/copied", SimInstant(9 * S));
    run!(format!("copy {r:?}"), d);
    for i in (0..24u64).step_by(3) {
        let key = format!("d/part-{i:02}");
        let (r, d) = store.delete_object("c", &key, SimInstant(10 * S + i));
        run!(format!("delete {key} {r:?}"), d);
    }
    // Listings straddling the consistency lags: mid-creation (some keys
    // still invisible), settled, mid-deletion (ghosts visible), and
    // fully settled. The visible (name, size) sequence is part of the
    // transcript — this is where stripe-overlay merge order would show.
    for now in [S, 3 * S, 10 * S + 12, 13 * S] {
        let (r, d) = store.list("c", "d/", None, SimInstant(now));
        let line = match r {
            Ok(l) => {
                let names: Vec<String> = l
                    .objects
                    .iter()
                    .map(|o| format!("{}:{}", o.name, o.size))
                    .collect();
                format!("list@{now} [{}]", names.join(","))
            }
            Err(e) => format!("list@{now} {e:?}"),
        };
        run!(line, d);
    }
    // Multipart: one upload completed, one stranded then swept.
    let (r, d) = store.initiate_multipart("c", "mp/done", Metadata::new(), SimInstant(20 * S));
    let done_id = *r.as_ref().unwrap();
    run!(format!("initiate mp/done {r:?}"), d);
    for (n, bytes) in [(1u32, 300usize), (2, 200)] {
        let (r, d) = store.upload_part(done_id, n, vec![n as u8; bytes]);
        run!(format!("upload_part {n} {r:?}"), d);
    }
    let (r, d) = store.complete_multipart(done_id, SimInstant(21 * S));
    run!(format!("complete {r:?}"), d);
    let (r, d) = store.initiate_multipart("c", "mp/stranded", Metadata::new(), SimInstant(22 * S));
    let stranded_id = *r.as_ref().unwrap();
    run!(format!("initiate mp/stranded {r:?}"), d);
    let (r, d) = store.upload_part(stranded_id, 1, vec![9u8; 500]);
    run!(format!("upload_part stranded {r:?}"), d);
    trace.push(format!(
        "stranded_bytes {}",
        store.debug_stranded_multipart_bytes()
    ));
    let (sweep, d) = store.sweep_stale_multiparts(SimInstant(400 * S), SimDuration::from_secs(60));
    run!(
        format!("sweep aborted={} freed={}", sweep.aborted, sweep.freed_bytes),
        d
    );
    trace.push(format!("in_flight {}", store.debug_multipart_in_flight()));
    (trace, virt.as_micros(), store.counters())
}

/// Run the scripted job at `stripes` against a fresh backend of `kind`
/// and return its transcript.
fn transcript(
    kind: &str,
    stripes: usize,
    faults: FaultSpec,
) -> (Vec<String>, u64, stocator::metrics::OpCounts) {
    let (backend, cleanup, _gateway): (BackendKind, Option<PathBuf>, Option<GatewayHandle>) =
        match kind {
            "mem" => (BackendKind::Mem, None, None),
            "sharded" => (BackendKind::Sharded(16), None, None),
            "fs" => {
                let root = unique_root("fs");
                (BackendKind::LocalFs(Some(root.clone())), Some(root), None)
            }
            "http" => {
                let inner = Arc::new(ShardedMemBackend::new(4));
                let server =
                    GatewayServer::bind("127.0.0.1:0", inner).expect("bind ephemeral gateway");
                let handle = server.spawn();
                let addr = handle.addr().to_string();
                (BackendKind::Http { addr, ns: None }, None, Some(handle))
            }
            other => panic!("unknown backend kind {other}"),
        };
    let store = striped_store(backend, stripes, faults);
    let out = scripted_job(&store);
    drop(store);
    if let Some(root) = cleanup {
        let _ = std::fs::remove_dir_all(&root);
    }
    out
}

/// The invariance criterion on every backend: the seed's single-lock
/// front end (`stripes: 1`) and the striped layout (`stripes: 16`, and
/// a deliberately-awkward prime count) produce byte-identical
/// transcripts — ops, outcomes, visible listings, virtual time,
/// counters.
#[test]
fn striping_is_invisible_on_every_backend() {
    for kind in ["mem", "sharded", "fs", "http"] {
        let legacy = transcript(kind, 1, FaultSpec::none());
        for stripes in [16usize, 7] {
            let striped = transcript(kind, stripes, FaultSpec::none());
            assert_eq!(
                legacy.0, striped.0,
                "{kind}: transcript changed at stripes={stripes}"
            );
            assert_eq!(
                legacy.1, striped.1,
                "{kind}: virtual runtime changed at stripes={stripes}"
            );
            assert_eq!(
                legacy.2, striped.2,
                "{kind}: op counters changed at stripes={stripes}"
            );
        }
    }
}

/// Same criterion with the fault plane armed: scheduled faults on PUT
/// and on a multipart part must fire at the same points and leave the
/// same trace whether or not the front end is striped (fault matching
/// consults the multipart stripe for the target key).
#[test]
fn fault_traces_are_striping_invariant() {
    let spec = FaultSpec::none()
        .with(FaultRule::new(FaultOp::Put, "d/", 3, 2))
        .with(FaultRule::new(FaultOp::UploadPart, "mp/", 1, 1))
        .with(FaultRule::new(FaultOp::Get, "d/part-07", 1, 1));
    let legacy = transcript("mem", 1, spec.clone());
    let striped = transcript("mem", 16, spec);
    assert_eq!(legacy.0, striped.0, "fault trace changed under striping");
    assert_eq!(legacy.1, striped.1, "faulted virtual runtime changed");
    assert_eq!(legacy.2, striped.2, "faulted op counters changed");
    // The spec really fired: some op in the transcript failed.
    assert!(
        legacy.0.iter().any(|l| l.contains("Err(")),
        "fault spec never fired: {:?}",
        legacy.0
    );
}

const WRITERS: usize = 16;
const ITERS: u64 = 512;

/// Lock-free accounting under real contention: 16 writer threads, each
/// issuing a fixed op mix against the striped front end, must land
/// EXACT counter totals — relaxed atomics lose no updates, and the
/// visibility/multipart stripes corrupt nothing. (Floors would pass
/// even with lost updates; equality is the point.)
#[test]
fn sixteen_writers_lose_no_counts() {
    let store = striped_store(BackendKind::Sharded(16), 16, FaultSpec::none());
    store.create_container("c", SimInstant::EPOCH).0.unwrap();
    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let store = &store;
            scope.spawn(move || {
                for i in 0..ITERS {
                    let key = format!("w{w:02}/part-{i:06}");
                    store
                        .put_object("c", &key, vec![7u8; 64], Metadata::new(), SimInstant(i))
                        .0
                        .unwrap();
                    store.get_object("c", &key).0.unwrap();
                    store.head_object("c", &key).0.unwrap();
                    if i % 8 == 7 {
                        store.delete_object("c", &key, SimInstant(i)).0.unwrap();
                    }
                    if i % 64 == 63 {
                        store
                            .list("c", &format!("w{w:02}/"), None, SimInstant(i))
                            .0
                            .unwrap();
                    }
                }
                // One multipart per thread: initiate + 2 parts + complete.
                let (r, _) = store.initiate_multipart(
                    "c",
                    &format!("w{w:02}/mp"),
                    Metadata::new(),
                    SimInstant(0),
                );
                let id = r.unwrap();
                store.upload_part(id, 1, vec![1u8; 64]).0.unwrap();
                store.upload_part(id, 2, vec![2u8; 64]).0.unwrap();
                store.complete_multipart(id, SimInstant(1)).0.unwrap();
            });
        }
    });
    let counts = store.counters();
    let w = WRITERS as u64;
    // Per thread: ITERS puts + initiate + 2 parts + complete = ITERS+4
    // PUT-class ops; plus the single create_container on the main thread.
    assert_eq!(counts.get(OpKind::PutObject), w * (ITERS + 4) + 1);
    assert_eq!(counts.get(OpKind::GetObject), w * ITERS);
    assert_eq!(counts.get(OpKind::HeadObject), w * ITERS);
    assert_eq!(counts.get(OpKind::DeleteObject), w * (ITERS / 8));
    assert_eq!(counts.get(OpKind::GetContainer), w * (ITERS / 64));
    // Bytes: every put and part is 64 bytes (data_scale 1, so unscaled);
    // every get reads the 64 bytes back.
    assert_eq!(counts.bytes_written, w * (ITERS + 2) * 64);
    assert_eq!(counts.bytes_read, w * ITERS * 64);
    // No multipart leaked and no tracker entry survived completion.
    assert_eq!(store.debug_multipart_in_flight(), 0);
    assert_eq!(store.debug_stranded_multipart_bytes(), 0);
}
