//! Failure injection across the consistency spectrum: what breaks, what
//! survives, and that Stocator's two read strategies both stay exact.

use std::sync::Arc;
use stocator::committer::CommitAlgorithm;
use stocator::connectors::{HadoopSwift, ReadStrategy, Stocator, StocatorConfig};
use stocator::fs::{FileSystem, OpCtx, Path};
use stocator::objectstore::{ConsistencyModel, LatencyModel, ObjectStore, StoreConfig};
use stocator::runtime::fallback::Fallback;
use stocator::runtime::Kernels;
use stocator::simclock::{SimDuration, SimInstant};
use stocator::spark::{ComputeModel, Driver, FaultKind, FaultPlan, SparkConfig, SparkJob, TaskResult};
use stocator::spark::task::{body, TaskBody};

fn store_with_lag(lag_s: u64) -> Arc<ObjectStore> {
    let store = ObjectStore::new(StoreConfig {
        latency: LatencyModel::instant(),
        consistency: ConsistencyModel::adversarial(SimDuration::from_secs(lag_s)),
        min_part_size: 0,
        seed: 0,
        ..StoreConfig::default()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    store
}

fn writer_tasks(n: usize) -> Vec<TaskBody> {
    (0..n)
        .map(|i| {
            body(move |run: &mut stocator::spark::TaskRun<'_>| {
                let name = run.part_basename();
                let written = run.write_part(&name, vec![i as u8; 50])?;
                Ok(TaskResult {
                    bytes_written: written,
                    records: 1,
                    ..Default::default()
                })
            })
        })
        .collect()
}

#[test]
fn legacy_connector_loses_output_under_listing_lag() {
    let store = store_with_lag(3600);
    let fs = HadoopSwift::new(store.clone());
    let mut driver = Driver::new(
        SparkConfig { slots: 4, ..Default::default() },
        fs,
        Some(store.clone()),
        ComputeModel::free(),
    );
    let job = SparkJob::new(
        "doomed",
        Some(Path::parse("swift://res/out").unwrap()),
        CommitAlgorithm::V1,
        writer_tasks(4),
    );
    let stats = driver.run_job(&job).unwrap();
    // The job "succeeds" — that is the insidious part (paper §2.2.2).
    assert!(stats.success);
    let finals = store
        .debug_names("res", "out/")
        .iter()
        .filter(|n| n.starts_with("out/part-"))
        .count();
    assert_eq!(finals, 0, "every part was silently lost by lagging listings");
}

#[test]
fn stocator_survives_listing_lag_with_manifest_reads() {
    let store = store_with_lag(3600);
    let fs = Stocator::new(
        store.clone(),
        StocatorConfig { read_strategy: ReadStrategy::Manifest, cache_capacity: 64 },
    );
    let mut driver = Driver::new(
        SparkConfig { slots: 4, ..Default::default() },
        fs.clone(),
        Some(store.clone()),
        ComputeModel::free(),
    );
    let job = SparkJob::new(
        "safe",
        Some(Path::parse("swift2d://res/out").unwrap()),
        CommitAlgorithm::V1,
        writer_tasks(4),
    );
    let stats = driver.run_job(&job).unwrap();
    assert!(stats.success);
    let mut ctx = OpCtx::new(SimInstant(stats.end.0));
    let listing = fs
        .list_status(&Path::parse("swift2d://res/out").unwrap(), &mut ctx)
        .unwrap();
    let parts = listing.iter().filter(|s| s.path.name().starts_with("part-")).count();
    assert_eq!(parts, 4);
}

#[test]
fn crash_retry_speculation_storm_still_yields_exact_output() {
    // Pile every fault type onto one job; the read side must still see
    // exactly one part per task with full content.
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut driver = Driver::new(
        SparkConfig {
            slots: 4,
            speculation: true,
            cleanup_speculation: false, // worst case: losers remain
            ..Default::default()
        },
        fs.clone(),
        Some(store.clone()),
        ComputeModel::free(),
    );
    let faults = FaultPlan::none()
        .with(0, 0, FaultKind::CrashBeforeWrite)
        .with(1, 0, FaultKind::CrashAfterPartialWrite { fraction: 0.4 })
        .with(2, 0, FaultKind::Straggle { extra: SimDuration::from_secs(500) });
    let job = SparkJob::new(
        "storm",
        Some(Path::parse("swift2d://res/out").unwrap()),
        CommitAlgorithm::V1,
        writer_tasks(6),
    )
    .with_faults(faults);
    let stats = driver.run_job(&job).unwrap();
    assert!(stats.success);
    assert!(stats.failed_attempts >= 2);
    assert_eq!(stats.speculative_attempts, 1);

    let mut ctx = OpCtx::new(SimInstant(stats.end.0));
    let listing = fs
        .list_status(&Path::parse("swift2d://res/out").unwrap(), &mut ctx)
        .unwrap();
    let parts: Vec<_> = listing
        .iter()
        .filter(|s| s.path.name().starts_with("part-"))
        .collect();
    assert_eq!(parts.len(), 6, "{parts:?}");
    for p in parts {
        assert_eq!(p.len, 50, "partial write must not win: {}", p.path);
        let data = fs.read_all(&p.path, &mut ctx).unwrap();
        assert_eq!(data.len(), 50);
    }
}

#[test]
fn kernels_work_inside_faulty_jobs() {
    // A compute-heavy task body using the kernel dispatcher under retries.
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut driver = Driver::new(
        SparkConfig { slots: 2, ..Default::default() },
        fs,
        Some(store),
        ComputeModel::free(),
    );
    let kernels = std::rc::Rc::new(Kernels::Native(Fallback));
    let tasks: Vec<TaskBody> = (0..2)
        .map(|_| {
            let kernels = kernels.clone();
            body(move |run: &mut stocator::spark::TaskRun<'_>| {
                let toks = stocator::runtime::pad_chunk(&[5i32, 9, 5], 0);
                let (hist, n) = kernels
                    .wordcount_chunk(&toks)
                    .map_err(|e| stocator::fs::FsError::Io(e.to_string()))?;
                assert_eq!(n, 3);
                assert_eq!(hist.iter().sum::<i32>(), 3);
                let name = run.part_basename();
                run.write_part(&name, vec![1u8; 8])?;
                Ok(TaskResult { records: n as u64, ..Default::default() })
            })
        })
        .collect();
    let job = SparkJob::new(
        "kern",
        Some(Path::parse("swift2d://res/k").unwrap()),
        CommitAlgorithm::V2,
        tasks,
    )
    .with_faults(FaultPlan::none().with(0, 0, FaultKind::CrashBeforeWrite));
    let stats = driver.run_job(&job).unwrap();
    assert!(stats.success);
    assert_eq!(stats.records, 6);
}
