//! XLA-vs-native kernel parity: the AOT-compiled artifacts must compute
//! exactly what the pure-Rust fallback (= ref.py) computes. Requires
//! `make artifacts`; skips (with a visible marker) when absent.

use stocator::runtime::{fallback::Fallback, Engine, Kernels, BUCKETS, CHUNK, GROUPS, PARTS};
use stocator::util::rng::Pcg32;

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: artifacts not available ({err}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn engine_loads_all_models() {
    let Some(e) = engine() else { return };
    let models = e.models();
    for m in [
        "wordcount_chunk",
        "terasort_partition_chunk",
        "readonly_chunk",
        "tpcds_agg_chunk",
    ] {
        assert!(models.contains(&m), "{models:?}");
    }
    assert_eq!(e.platform, "cpu");
}

#[test]
fn wordcount_parity() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(42);
    for case in 0..5 {
        let n = rng.range(0, CHUNK + 1);
        let mut toks = vec![0i32; CHUNK];
        for t in toks.iter_mut().take(n) {
            *t = rng.range(1, 1 << 20) as i32;
        }
        let (xh, xn) = e.wordcount_chunk(&toks).unwrap();
        let (nh, nn) = Fallback.wordcount_chunk(&toks);
        assert_eq!(xn, nn, "case {case}");
        assert_eq!(xh, nh, "case {case}");
        assert_eq!(xh.len(), BUCKETS);
    }
}

#[test]
fn terasort_parity() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(43);
    for case in 0..5 {
        let keys: Vec<i32> = (0..CHUNK).map(|_| rng.range(0, 1 << 20) as i32).collect();
        let mut splitters: Vec<i32> =
            (0..PARTS - 1).map(|_| rng.range(0, 1 << 20) as i32).collect();
        splitters.sort();
        let (xa, xh) = e.terasort_partition_chunk(&keys, &splitters).unwrap();
        let (na, nh) = Fallback.terasort_partition_chunk(&keys, &splitters);
        assert_eq!(xa, na, "case {case}");
        assert_eq!(xh, nh, "case {case}");
    }
}

#[test]
fn readonly_parity() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(44);
    for _ in 0..5 {
        let n = rng.range(0, CHUNK + 1);
        let mut bytes = vec![0i32; CHUNK];
        for b in bytes.iter_mut().take(n) {
            *b = rng.range(1, 256) as i32;
        }
        assert_eq!(
            e.readonly_chunk(&bytes).unwrap(),
            Fallback.readonly_chunk(&bytes)
        );
    }
}

#[test]
fn tpcds_parity() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(45);
    for case in 0..5 {
        let keys: Vec<i32> = (0..CHUNK)
            .map(|_| rng.range(0, GROUPS + 8) as i32 - 4)
            .collect();
        let vals: Vec<f32> = (0..CHUNK).map(|_| rng.next_f64() as f32).collect();
        let (xs, xc) = e.tpcds_agg_chunk(&keys, &vals).unwrap();
        let (ns, nc) = Fallback.tpcds_agg_chunk(&keys, &vals);
        assert_eq!(xc, nc, "case {case}");
        for g in 0..GROUPS {
            assert!(
                (xs[g] - ns[g]).abs() < 1e-3,
                "case {case} group {g}: {} vs {}",
                xs[g],
                ns[g]
            );
        }
    }
}

#[test]
fn kernels_dispatcher_prefers_xla_when_available() {
    let k = Kernels::load_or_fallback("artifacts");
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        assert_eq!(k.backend_name(), "xla-pjrt");
    } else {
        assert_eq!(k.backend_name(), "native-fallback");
    }
}
