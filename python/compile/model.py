"""L2 — the jitted per-chunk compute graphs the Spark-simulator tasks run.

Each model function wraps one or more L1 Pallas kernels (plus any glue
math) into a single jax function with **static shapes**, lowered once by
``aot.py`` into one fused HLO module per function. The rust runtime
(rust/src/runtime) loads the HLO artifacts and invokes them from task
bodies; Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    BUCKETS,
    CHUNK,
    GROUPS,
    PARTS,
    group_agg,
    hash_count,
    line_stats,
    range_partition,
)


def wordcount_chunk(tokens):
    """Wordcount map-side: token-id chunk -> (bucket histogram, token count).

    tokens: int32[CHUNK], 0 = padding (token ids start at 1).
    """
    counts = hash_count(tokens)
    n_tokens = (tokens != 0).astype(jnp.int32).sum()
    # Padding tokens hash into some bucket; subtract them from that bucket.
    pad = (tokens == 0).astype(jnp.int32).sum()
    zero_bucket = jnp.zeros((BUCKETS,), jnp.int32).at[0].set(pad)
    # hash(0) = 0 -> bucket 0.
    return (counts - zero_bucket, n_tokens)


def terasort_partition_chunk(keys, splitters):
    """Terasort stage-1: keys -> (partition assignment, partition histogram).

    keys: int32[CHUNK] (padding = INT32_MAX routes to the last partition),
    splitters: int32[PARTS-1] ascending.
    """
    assign, hist = range_partition(keys, splitters)
    return (assign, hist)


def readonly_chunk(chunk_bytes):
    """Read-only benchmark: byte chunk -> [newlines, nonzero bytes]."""
    return (line_stats(chunk_bytes),)


def tpcds_agg_chunk(keys, vals):
    """TPC-DS group-by: (group keys, values) -> (sums, counts).

    keys: int32[CHUNK] with -1 for filtered-out rows; vals: float32[CHUNK].
    """
    sums, counts = group_agg(keys, vals)
    return (sums, counts)


#: name -> (function, example argument shapes) — the AOT manifest.
MODELS = {
    "wordcount_chunk": (
        wordcount_chunk,
        (jax.ShapeDtypeStruct((CHUNK,), jnp.int32),),
    ),
    "terasort_partition_chunk": (
        terasort_partition_chunk,
        (
            jax.ShapeDtypeStruct((CHUNK,), jnp.int32),
            jax.ShapeDtypeStruct((PARTS - 1,), jnp.int32),
        ),
    ),
    "readonly_chunk": (
        readonly_chunk,
        (jax.ShapeDtypeStruct((CHUNK,), jnp.int32),),
    ),
    "tpcds_agg_chunk": (
        tpcds_agg_chunk,
        (
            jax.ShapeDtypeStruct((CHUNK,), jnp.int32),
            jax.ShapeDtypeStruct((CHUNK,), jnp.float32),
        ),
    ),
}
