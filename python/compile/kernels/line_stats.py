"""Read-only workload kernel: text-chunk statistics.

Counts newline bytes (the Read-only benchmark counts lines) and non-zero
bytes (chunks are zero-padded to ``CHUNK``; the byte count validates that
padding is accounted). A pure compare+reduce over a VMEM tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import CHUNK

BLOCK = 1024
NEWLINE = 10  # b"\n"


def _kernel(byte_ref, o_ref):
    b = byte_ref[...]
    newlines = (b == NEWLINE).astype(jnp.int32).sum()
    nonzero = (b != 0).astype(jnp.int32).sum()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.stack([newlines, nonzero])


def line_stats(chunk_bytes):
    """chunk_bytes: int32[CHUNK] (byte values 0..255, 0 = padding)
    -> int32[2]: [newline count, non-zero byte count]."""
    assert chunk_bytes.shape == (CHUNK,), chunk_bytes.shape
    return pl.pallas_call(
        _kernel,
        grid=(CHUNK // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=True,
    )(chunk_bytes)
