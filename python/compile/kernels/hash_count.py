"""Wordcount hash-histogram kernel.

Maps a chunk of token ids to a histogram over ``BUCKETS`` hash buckets:
``counts[b] = |{ i : hash(tok[i]) mod BUCKETS == b }|``.

TPU mapping (DESIGN.md §Hardware-Adaptation): a scatter-add histogram is
hostile to the MXU/VPU, so the reduction is expressed as a **one-hot
compare + sum** — an ``[BUCKETS, BLOCK]`` mask reduced along the block
axis, which lowers to vectorized compare + reduce (and, fused with a
matmul-shaped contraction, lands on the MXU for the f32 variant in
``group_agg``). The grid walks ``CHUNK/BLOCK`` tiles so only
``BUCKETS x BLOCK`` i32 (512x512x4 B = 1 MiB) of one-hot mask plus the
``BUCKETS`` accumulator live in VMEM at a time.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BUCKETS, CHUNK

#: Tile width per grid step (VMEM working set: BUCKETS*BLOCK*4 bytes).
BLOCK = 512

#: Knuth multiplicative hash constant (2^32 / phi).
HASH_MULT = 2654435761


def _kernel(tok_ref, o_ref):
    toks = tok_ref[...]
    h = (toks.astype(jnp.uint32) * jnp.uint32(HASH_MULT)) % jnp.uint32(BUCKETS)
    buckets = jax.lax.broadcasted_iota(jnp.uint32, (BUCKETS, BLOCK), 0)
    onehot = (h[None, :] == buckets).astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += onehot.sum(axis=1)


def hash_count(tokens):
    """tokens: int32[CHUNK] -> int32[BUCKETS] bucket histogram."""
    assert tokens.shape == (CHUNK,), tokens.shape
    return pl.pallas_call(
        _kernel,
        grid=(CHUNK // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BUCKETS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((BUCKETS,), jnp.int32),
        interpret=True,
    )(tokens)
