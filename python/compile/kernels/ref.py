"""Pure-jnp oracles for every kernel — the CORE correctness signal.

Each function computes the same result as its Pallas counterpart with no
pallas_call involved; pytest asserts exact agreement, and the rust runtime's
fallback implementations (rust/src/runtime/fallback.rs) mirror these.
"""

import jax.numpy as jnp

from . import BUCKETS, GROUPS, PARTS
from .hash_count import HASH_MULT
from .line_stats import NEWLINE


def hash_count_ref(tokens):
    h = (tokens.astype(jnp.uint32) * jnp.uint32(HASH_MULT)) % jnp.uint32(BUCKETS)
    return jnp.bincount(h.astype(jnp.int32), length=BUCKETS).astype(jnp.int32)


def range_partition_ref(keys, splitters):
    assign = (keys[:, None] >= splitters[None, :]).astype(jnp.int32).sum(axis=1)
    hist = jnp.bincount(assign, length=PARTS).astype(jnp.int32)
    return assign, hist


def line_stats_ref(chunk_bytes):
    newlines = (chunk_bytes == NEWLINE).astype(jnp.int32).sum()
    nonzero = (chunk_bytes != 0).astype(jnp.int32).sum()
    return jnp.stack([newlines, nonzero])


def group_agg_ref(keys, vals):
    mask = (keys[:, None] == jnp.arange(GROUPS)[None, :]).astype(jnp.float32)
    sums = (mask * vals[:, None]).sum(axis=0)
    counts = mask.sum(axis=0).astype(jnp.int32)
    return sums, counts
