"""TPC-DS group-by aggregation kernel.

Grouped sum + count over a chunk: ``sums[g] = sum(vals[i] where key[i]==g)``
and ``counts[g]`` likewise. Keys outside ``[0, GROUPS)`` (e.g. filtered-out
rows marked -1) contribute nothing.

TPU mapping: the grouped sum is a genuine MXU contraction — the one-hot
mask ``[GROUPS, BLOCK]`` f32 matrix multiplies the value vector
``[BLOCK]``, i.e. a (GROUPS x BLOCK) x (BLOCK x 1) matmul per tile, which
is exactly the shape the systolic array wants (GROUPS=64, BLOCK=512 tiles
pad cleanly to the 128x128 MXU with bf16/f32 accumulation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import CHUNK, GROUPS

BLOCK = 512


def _kernel(key_ref, val_ref, sum_ref, cnt_ref):
    keys = key_ref[...]
    vals = val_ref[...]
    groups = jax.lax.broadcasted_iota(jnp.int32, (GROUPS, BLOCK), 0)
    onehot = (keys[None, :] == groups).astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # The MXU-shaped contraction: [GROUPS, BLOCK] @ [BLOCK] -> [GROUPS].
    sum_ref[...] += onehot @ vals
    cnt_ref[...] += onehot.sum(axis=1).astype(jnp.int32)


def group_agg(keys, vals):
    """keys: int32[CHUNK] (group id or -1), vals: float32[CHUNK]
    -> (sums float32[GROUPS], counts int32[GROUPS])."""
    assert keys.shape == (CHUNK,), keys.shape
    assert vals.shape == (CHUNK,), vals.shape
    return pl.pallas_call(
        _kernel,
        grid=(CHUNK // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((GROUPS,), lambda i: (0,)),
            pl.BlockSpec((GROUPS,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((GROUPS,), jnp.float32),
            jax.ShapeDtypeStruct((GROUPS,), jnp.int32),
        ],
        interpret=True,
    )(keys, vals)
