"""L1 — Pallas kernels for the workloads' compute hot-spots.

These are the per-chunk primitives the Spark-simulator tasks execute through
the AOT-compiled XLA artifacts (rust/src/runtime). All kernels run with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic custom-calls,
so interpret mode is the correctness path and TPU efficiency is argued
structurally (DESIGN.md §Hardware-Adaptation).

Fixed shapes (AOT requires static shapes; the rust runtime pads chunks):

=============  =====================================================
``CHUNK``      elements per input chunk (tokens / keys / bytes)
``BUCKETS``    wordcount hash-histogram width
``PARTS``      terasort range-partition fan-out
``GROUPS``     TPC-DS group-by fan-out
=============  =====================================================
"""

CHUNK = 4096
BUCKETS = 512
PARTS = 64
GROUPS = 64

from .hash_count import hash_count          # noqa: E402,F401
from .range_partition import range_partition  # noqa: E402,F401
from .line_stats import line_stats          # noqa: E402,F401
from .group_agg import group_agg            # noqa: E402,F401
