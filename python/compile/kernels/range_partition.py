"""Terasort range-partition kernel.

Given a chunk of sort keys and ``PARTS-1`` splitters (ascending), computes
for each key its partition index ``p[i] = |{ s : key[i] >= splitter[s] }|``
and the per-partition histogram.

TPU mapping: the partition index is a broadcast compare against the
splitter vector reduced along the splitter axis ([BLOCK, PARTS-1] mask),
and the histogram reuses the one-hot reduction of ``hash_count`` — both
vectorize on the VPU with no scatter. Splitters are tiny and live in VMEM
for the whole grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import CHUNK, PARTS

BLOCK = 512


def _kernel(key_ref, split_ref, assign_ref, hist_ref):
    keys = key_ref[...]
    splits = split_ref[...]
    # assign[i] = number of splitters <= key  (splitters ascending)
    ge = (keys[:, None] >= splits[None, :]).astype(jnp.int32)
    assign = ge.sum(axis=1)
    assign_ref[...] = assign

    parts = jax.lax.broadcasted_iota(jnp.int32, (PARTS, BLOCK), 0)
    onehot = (assign[None, :] == parts).astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    hist_ref[...] += onehot.sum(axis=1)


def range_partition(keys, splitters):
    """keys: int32[CHUNK], splitters: int32[PARTS-1] (ascending)
    -> (assign int32[CHUNK], hist int32[PARTS])."""
    assert keys.shape == (CHUNK,), keys.shape
    assert splitters.shape == (PARTS - 1,), splitters.shape
    return pl.pallas_call(
        _kernel,
        grid=(CHUNK // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((PARTS - 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((PARTS,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((CHUNK,), jnp.int32),
            jax.ShapeDtypeStruct((PARTS,), jnp.int32),
        ],
        interpret=True,
    )(keys, splitters)
