"""AOT lowering: jax/pallas models -> HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and its README.

Usage:  python -m compile.aot [--out-dir ../artifacts]

Writes one ``<model>.hlo.txt`` per entry in ``model.MODELS`` plus a
``manifest.txt`` recording names, shapes and the shared constants so the
rust runtime can sanity-check at load time.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels import BUCKETS, CHUNK, GROUPS, PARTS
from .model import MODELS


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file stamp")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = [
        f"constants\tCHUNK={CHUNK}\tBUCKETS={BUCKETS}\tPARTS={PARTS}\tGROUPS={GROUPS}"
    ]
    for name, (fn, example_args) in MODELS.items():
        text = to_hlo_text(fn, example_args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ",".join(
            f"{a.dtype}[{'x'.join(map(str, a.shape))}]" for a in example_args
        )
        manifest.append(f"model\t{name}\t{shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    # Legacy stamp for Makefile dependency tracking.
    if args.out:
        with open(args.out, "w") as f:
            f.write("see per-model .hlo.txt files\n")
    print(f"wrote {out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
