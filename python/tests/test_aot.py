"""AOT pipeline: models lower to parseable HLO text artifacts."""

import os
import subprocess
import sys

import pytest

from compile.aot import to_hlo_text
from compile.model import MODELS


@pytest.mark.parametrize("name", sorted(MODELS))
def test_hlo_text_structure(name):
    fn, example_args = MODELS[name]
    text = to_hlo_text(fn, example_args)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root computation returns a tuple.
    assert "tuple" in text.lower()


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    files = sorted(p.name for p in out.iterdir())
    for name in MODELS:
        assert f"{name}.hlo.txt" in files
    assert "manifest.txt" in files
    manifest = (out / "manifest.txt").read_text()
    assert manifest.startswith("constants\tCHUNK=")
    assert manifest.count("model\t") == len(MODELS)
