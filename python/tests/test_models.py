"""L2 model functions: shapes, semantics, and jit-lowerability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import BUCKETS, CHUNK, GROUPS, PARTS
from compile.model import (
    MODELS,
    readonly_chunk,
    terasort_partition_chunk,
    tpcds_agg_chunk,
    wordcount_chunk,
)


def test_wordcount_chunk_discounts_padding():
    toks = np.zeros(CHUNK, np.int32)
    toks[:100] = np.arange(1, 101)
    counts, n = wordcount_chunk(jnp.asarray(toks))
    assert int(n) == 100
    assert counts.shape == (BUCKETS,)
    assert int(counts.sum()) == 100, "padding must not be counted"
    assert int(np.asarray(counts).min()) >= 0


def test_wordcount_chunk_full():
    rng = np.random.default_rng(5)
    toks = rng.integers(1, 1 << 20, CHUNK, dtype=np.int32)
    counts, n = wordcount_chunk(jnp.asarray(toks))
    assert int(n) == CHUNK
    assert int(counts.sum()) == CHUNK


def test_terasort_partition_chunk_shapes():
    rng = np.random.default_rng(6)
    keys = jnp.asarray(rng.integers(0, 1 << 20, CHUNK, dtype=np.int32))
    splits = jnp.asarray(np.sort(rng.integers(0, 1 << 20, PARTS - 1, dtype=np.int32)))
    assign, hist = terasort_partition_chunk(keys, splits)
    assert assign.shape == (CHUNK,)
    assert hist.shape == (PARTS,)
    assert int(hist.sum()) == CHUNK


def test_readonly_chunk():
    arr = np.zeros(CHUNK, np.int32)
    arr[:3] = [10, 65, 10]  # "\nA\n"
    (stats,) = readonly_chunk(jnp.asarray(arr))
    assert int(stats[0]) == 2
    assert int(stats[1]) == 3


def test_tpcds_agg_chunk():
    rng = np.random.default_rng(7)
    keys = rng.integers(-1, GROUPS, CHUNK, dtype=np.int32)
    vals = rng.random(CHUNK, dtype=np.float32)
    sums, counts = tpcds_agg_chunk(jnp.asarray(keys), jnp.asarray(vals))
    assert sums.shape == (GROUPS,)
    assert int(counts.sum()) == int((keys >= 0).sum())


def test_all_models_lower_to_stablehlo():
    # Every model must lower with static shapes (the AOT contract).
    for name, (fn, example_args) in MODELS.items():
        lowered = jax.jit(fn).lower(*example_args)
        ir = str(lowered.compiler_ir("stablehlo"))
        assert "func.func public @main" in ir, name


def test_models_are_deterministic():
    rng = np.random.default_rng(8)
    toks = jnp.asarray(rng.integers(1, 1000, CHUNK, dtype=np.int32))
    a, _ = wordcount_chunk(toks)
    b, _ = wordcount_chunk(toks)
    np.testing.assert_array_equal(a, b)
