"""Pallas kernels vs pure-jnp oracles — exact agreement, hypothesis-swept.

Shapes are fixed by the AOT contract (CHUNK etc.), so hypothesis sweeps the
*value space*: uniform, adversarial (all-equal, all-padding, extremes) and
random inputs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    BUCKETS,
    CHUNK,
    GROUPS,
    PARTS,
    group_agg,
    hash_count,
    line_stats,
    range_partition,
)
from compile.kernels.ref import (
    group_agg_ref,
    hash_count_ref,
    line_stats_ref,
    range_partition_ref,
)

SETTINGS = settings(max_examples=25, deadline=None)


def rand_tokens(seed, lo=0, hi=1 << 20):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=CHUNK, dtype=np.int32))


# ---------- hash_count -------------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**32 - 1))
def test_hash_count_matches_ref(seed):
    toks = rand_tokens(seed)
    np.testing.assert_array_equal(hash_count(toks), hash_count_ref(toks))


def test_hash_count_conserves_mass():
    toks = rand_tokens(7)
    assert int(hash_count(toks).sum()) == CHUNK


@pytest.mark.parametrize("value", [0, 1, 2**31 - 1, 12345])
def test_hash_count_constant_input(value):
    toks = jnp.full((CHUNK,), value, jnp.int32)
    out = np.asarray(hash_count(toks))
    assert out.sum() == CHUNK
    assert (out > 0).sum() == 1  # everything in one bucket


# ---------- range_partition --------------------------------------------------


def make_splitters(seed):
    rng = np.random.default_rng(seed)
    s = np.sort(rng.integers(0, 1 << 20, size=PARTS - 1, dtype=np.int32))
    return jnp.asarray(s)


@SETTINGS
@given(seed=st.integers(0, 2**32 - 1))
def test_range_partition_matches_ref(seed):
    keys = rand_tokens(seed)
    splits = make_splitters(seed ^ 0xABCD)
    a, h = range_partition(keys, splits)
    ra, rh = range_partition_ref(keys, splits)
    np.testing.assert_array_equal(a, ra)
    np.testing.assert_array_equal(h, rh)


def test_range_partition_is_monotone():
    keys = jnp.asarray(np.arange(CHUNK, dtype=np.int32) * 251)
    splits = make_splitters(3)
    a, h = range_partition(keys, splits)
    a = np.asarray(a)
    assert (np.diff(a) >= 0).all(), "ascending keys -> ascending partitions"
    assert int(h.sum()) == CHUNK
    assert a.min() >= 0 and a.max() < PARTS


def test_range_partition_extremes():
    splits = make_splitters(5)
    lo = jnp.full((CHUNK,), -(2**31), jnp.int32)
    hi = jnp.full((CHUNK,), 2**31 - 1, jnp.int32)
    a_lo, _ = range_partition(lo, splits)
    a_hi, _ = range_partition(hi, splits)
    assert np.asarray(a_lo).max() == 0
    assert np.asarray(a_hi).min() == PARTS - 1


# ---------- line_stats -------------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**32 - 1), pad=st.integers(0, CHUNK))
def test_line_stats_matches_ref(seed, pad):
    rng = np.random.default_rng(seed)
    b = rng.integers(1, 256, size=CHUNK, dtype=np.int32)
    if pad:
        b[CHUNK - pad :] = 0
    b = jnp.asarray(b)
    np.testing.assert_array_equal(line_stats(b), line_stats_ref(b))


def test_line_stats_counts_newlines_exactly():
    text = b"hello\nworld\n\nxyz"
    arr = np.zeros(CHUNK, np.int32)
    arr[: len(text)] = np.frombuffer(text, np.uint8)
    out = np.asarray(line_stats(jnp.asarray(arr)))
    assert out[0] == 3
    assert out[1] == len(text)  # no zero bytes in the text itself


# ---------- group_agg --------------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**32 - 1), filtered=st.floats(0.0, 1.0))
def test_group_agg_matches_ref(seed, filtered):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, GROUPS, size=CHUNK, dtype=np.int32)
    mask = rng.random(CHUNK) < filtered
    keys[mask] = -1  # filtered-out rows
    vals = rng.random(CHUNK, dtype=np.float32)
    sums, counts = group_agg(jnp.asarray(keys), jnp.asarray(vals))
    rsums, rcounts = group_agg_ref(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-5)
    np.testing.assert_array_equal(counts, rcounts)


def test_group_agg_against_numpy_groupby():
    rng = np.random.default_rng(11)
    keys = rng.integers(0, GROUPS, size=CHUNK, dtype=np.int32)
    vals = rng.random(CHUNK, dtype=np.float32)
    sums, counts = group_agg(jnp.asarray(keys), jnp.asarray(vals))
    for g in range(0, GROUPS, 7):
        sel = keys == g
        np.testing.assert_allclose(
            float(np.asarray(sums)[g]), float(vals[sel].sum()), rtol=1e-4
        )
        assert int(np.asarray(counts)[g]) == int(sel.sum())


def test_group_agg_ignores_filtered_rows():
    keys = jnp.full((CHUNK,), -1, jnp.int32)
    vals = jnp.ones((CHUNK,), jnp.float32)
    sums, counts = group_agg(keys, vals)
    assert float(jnp.abs(sums).sum()) == 0.0
    assert int(counts.sum()) == 0
