//! REST-call cost analysis (paper Table 8): what one Teragen run costs in
//! request fees on each provider's 2017 price sheet, per scenario.
//!
//!   cargo run --release --example cost_analysis

use stocator::harness::{run_cell, Scenario, Sizing, Workload};
use stocator::objectstore::{cost_usd, PROVIDERS};
use stocator::util::table::Table;

fn main() {
    let sizing = Sizing::paper();
    let mut t = Table::new(
        "Teragen (46.5 GB, 372 parts): REST-call cost per provider (USD)",
        &["scenario", "IBM", "AWS", "Google", "Azure", "avg", "x Stocator"],
    );
    let stocator_avg = {
        let c = run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
        cost_usd(&c.ops)
    };
    for s in Scenario::ALL {
        let cell = run_cell(s, Workload::Teragen, &sizing, 1);
        let mut row = vec![s.label().to_string()];
        for p in PROVIDERS {
            row.push(format!("{:.5}", p.cost(&cell.ops)));
        }
        let avg = cost_usd(&cell.ops);
        row.push(format!("{avg:.5}"));
        row.push(format!("x{:.2}", avg / stocator_avg));
        t.row(row);
    }
    print!("{}", t.render());
    println!("\npaper Table 8 (Teragen column): H-S Base x8.23, S3a Base x27.82,");
    println!("H-S Cv2 x5.24, S3a Cv2 x17.59, S3a Cv2+FU x17.55");
}
