//! Eventual consistency (paper §2.2.2 vs §3.2): under a lagging container
//! listing, the rename-based committers silently lose output, while
//! Stocator's manifest read path stays exact.
//!
//!   cargo run --release --example eventual_consistency

use stocator::committer::{CommitAlgorithm, Committer, JobContext, TaskAttemptContext};
use stocator::connectors::naming::AttemptId;
use stocator::connectors::{HadoopSwift, ReadStrategy, Stocator, StocatorConfig};
use stocator::fs::{FileSystem, OpCtx, Path};
use stocator::objectstore::{ConsistencyModel, LatencyModel, ObjectStore, StoreConfig};
use stocator::simclock::{SimDuration, SimInstant};

fn adversarial_store() -> std::sync::Arc<ObjectStore> {
    let store = ObjectStore::new(StoreConfig {
        latency: LatencyModel::instant(),
        consistency: ConsistencyModel::adversarial(SimDuration::from_secs(3600)),
        min_part_size: 0,
        seed: 0,
        ..StoreConfig::default()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    store
}

fn run_job(fs: &dyn FileSystem, scheme: &str, parts: usize) {
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let out = Path::parse(&format!("{scheme}://res/out")).unwrap();
    let job = JobContext::new(out);
    let committer = Committer::new(CommitAlgorithm::V1);
    committer.setup_job(fs, &job, &mut ctx).unwrap();
    for t in 0..parts as u32 {
        let tac = TaskAttemptContext::new(&job, AttemptId::new("1", "0000", t, 0));
        committer.setup_task(fs, &tac, &mut ctx).unwrap();
        committer
            .write_part(fs, &tac, &format!("part-{t:05}"), vec![t as u8; 64], &mut ctx)
            .unwrap();
        committer.commit_task(fs, &tac, &mut ctx).unwrap();
    }
    committer.commit_job(fs, &job, &mut ctx).unwrap();
}

fn main() {
    const PARTS: usize = 5;
    println!("listings lag mutations by 1 hour (adversarial model)\n");

    // Legacy connector: the commit-time listings miss everything.
    let store = adversarial_store();
    let swift = HadoopSwift::new(store.clone());
    run_job(&*swift, "swift", PARTS);
    let final_parts = store
        .debug_names("res", "out/")
        .iter()
        .filter(|n| n.contains("part-") && !n.contains("_temporary"))
        .count();
    println!("Hadoop-Swift v1: {final_parts}/{PARTS} parts reached their final names");
    assert_eq!(final_parts, 0, "expected total output loss");

    // Stocator, manifest read strategy: exact output despite the lag.
    let store = adversarial_store();
    let stoc = Stocator::new(
        store.clone(),
        StocatorConfig {
            read_strategy: ReadStrategy::Manifest,
            cache_capacity: 64,
        },
    );
    run_job(&*stoc, "swift2d", PARTS);
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let listing = stoc
        .list_status(&Path::parse("swift2d://res/out").unwrap(), &mut ctx)
        .unwrap();
    let parts = listing
        .iter()
        .filter(|s| s.path.name().starts_with("part-"))
        .count();
    println!("Stocator (manifest): {parts}/{PARTS} parts readable");
    assert_eq!(parts, PARTS);
    println!("\nStocator never lists at commit time and reconstructs part names");
    println!("from the _SUCCESS manifest at read time (paper §3.2, option 2).");
}
