//! END-TO-END DRIVER (DESIGN.md deliverable (b) / EXPERIMENTS.md §E2E):
//! runs a real multi-job pipeline through ALL THREE LAYERS —
//!
//!   L1/L2: the AOT-compiled JAX/Pallas kernels (`artifacts/*.hlo.txt`,
//!          loaded via PJRT; falls back to native kernels with a warning
//!          if `make artifacts` has not run),
//!   L3:    the Rust coordinator: object store, Stocator connector,
//!          commit protocol, Spark engine —
//!
//! on a real small workload: Teragen generates a dataset, Terasort sorts
//! it globally, Wordcount counts a Zipf corpus, and the TPC-DS subset runs
//! its 8 queries; every output is validated against an independent oracle
//! and the paper's headline metric (REST ops vs the legacy connector) is
//! reported.
//!
//!   make artifacts && cargo run --release --example end_to_end_pipeline

use std::rc::Rc;
use stocator::harness::scenarios::{build_env, Scenario, Sizing};
use stocator::harness::Workload;
use stocator::metrics::OpKind;
use stocator::query::datagen::StarSchema;
use stocator::runtime::Kernels;
use stocator::workloads::{input, terasort, tpcds, wordcount, WorkloadReport};

fn report(stage: &str, r: &WorkloadReport) {
    println!(
        "  {:<10} sim-runtime {:>8.2}s  REST ops {:>6}  GET {:>5} PUT {:>5} COPY {:>3}  -> {}",
        stage,
        r.runtime.as_secs_f64(),
        r.ops.total(),
        r.ops.get(OpKind::GetObject),
        r.ops.get(OpKind::PutObject),
        r.ops.get(OpKind::CopyObject),
        match &r.validation {
            Ok(s) => format!("OK: {s}"),
            Err(e) => format!("FAILED: {e}"),
        }
    );
    assert!(r.is_valid(), "{stage} failed validation");
}

fn main() {
    let kernels = Rc::new(Kernels::load_or_fallback("artifacts"));
    println!("kernel backend: {}", kernels.backend_name());

    let mut sizing = Sizing::small();
    sizing.parts = 24;
    sizing.part_bytes = 25 * 1024;
    sizing.slots = 12;

    // ---- Teragen -> Terasort on Stocator, XLA kernels on the hot path.
    let mut env = build_env(Scenario::Stocator, &sizing, "terasort", sizing.data_scale, sizing.parts, 7);
    env.kernels = kernels.clone();
    println!("\npipeline 1: teragen -> terasort (Stocator, {} parts):", sizing.parts);
    let gen = stocator::workloads::teragen::run(&mut env, "tera-in");
    report("teragen", &gen);
    let sorted = terasort::run(&mut env, "tera-in", "tera-sorted");
    report("terasort", &sorted);

    // ---- Wordcount.
    let mut env = build_env(Scenario::Stocator, &sizing, "wordcount", sizing.data_scale, sizing.parts, 8);
    env.kernels = kernels.clone();
    let (_, words, _) =
        input::upload_text_dataset(&env.store, "res", "corpus", sizing.parts, sizing.part_bytes, 8);
    println!("\npipeline 2: wordcount over a {}-part Zipf corpus ({words} words):", sizing.parts);
    let wc = wordcount::run(&mut env, "corpus", "wc-out", words);
    report("wordcount", &wc);

    // ---- TPC-DS subset.
    let mut env = build_env(Scenario::Stocator, &sizing, "tpcds", sizing.tpcds_scale, sizing.tpcds_shards, 9);
    env.kernels = kernels.clone();
    let schema = StarSchema::new(9, sizing.tpcds_shards, sizing.tpcds_rows);
    tpcds::upload_star_schema(&env, "sales", &schema);
    println!("\npipeline 3: TPC-DS subset (8 queries, {} shards):", sizing.tpcds_shards);
    let ds = tpcds::run(&mut env, "sales", &schema);
    report("tpcds", &ds);

    // ---- Headline metric: REST ops vs the legacy baseline.
    println!("\nheadline (paper Tables 6/7): Stocator vs S3a Base on Teragen:");
    let st = stocator::harness::run_cell(Scenario::Stocator, Workload::Teragen, &sizing, 1);
    let s3 = stocator::harness::run_cell(Scenario::S3aBase, Workload::Teragen, &sizing, 1);
    println!(
        "  Stocator: {:>7.1}s, {:>6} ops | S3a Base: {:>7.1}s, {:>6} ops | speedup x{:.1}, op ratio x{:.1}",
        st.runtime_mean_s,
        st.ops.total(),
        s3.runtime_mean_s,
        s3.ops.total(),
        s3.runtime_mean_s / st.runtime_mean_s,
        s3.ops.total() as f64 / st.ops.total() as f64,
    );
    assert!(st.valid && s3.valid);
    assert!(s3.runtime_mean_s > st.runtime_mean_s * 2.0, "speedup shape");
    println!("\nend_to_end_pipeline OK (all layers composed, all outputs validated)");
}
