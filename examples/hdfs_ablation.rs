//! Ablation (paper §2.2.2): the "copy input to HDFS, compute, copy back"
//! workaround vs computing directly on the object store with Stocator.
//! The workaround avoids eventual consistency but pays two full dataset
//! transfers; Stocator avoids both.
//!
//!   cargo run --release --example hdfs_ablation

use stocator::harness::scenarios::{build_env, compute_rate, Scenario, Sizing};
use stocator::simclock::SimDuration;
use stocator::workloads::{input, teragen};

fn main() {
    let sizing = Sizing::paper();
    // Direct: Teragen straight onto the object store through Stocator.
    let mut env = build_env(
        Scenario::Stocator,
        &sizing,
        "teragen",
        sizing.data_scale,
        sizing.parts,
        3,
    );
    let direct = teragen::run(&mut env, "teraout");
    assert!(direct.is_valid());
    println!(
        "direct (Stocator):              {:>7.1}s, {} REST ops",
        direct.runtime.as_secs_f64(),
        direct.ops.total()
    );

    // Workaround: generate into HDFS (fast local writes), then copy the
    // result up to the object store — one extra full-dataset transfer.
    // Model: HDFS write at disk bandwidth + 372 parallel uploads.
    let gen_time = {
        // same compute as the direct run
        let per_task = sizing.part_bytes as u64 * sizing.data_scale / compute_rate("teragen");
        let waves = (sizing.parts as u64).div_ceil(sizing.slots as u64);
        // HDFS write ~400 MB/s effective (3-replica pipeline)
        let hdfs_write = sizing.part_bytes as u64 * sizing.data_scale / 400_000_000;
        SimDuration::from_secs(waves * (per_task + hdfs_write))
    };
    let mut env2 = build_env(
        Scenario::Stocator,
        &sizing,
        "copy",
        sizing.data_scale,
        sizing.parts,
        4,
    );
    // Upload phase == a Copy workload whose read side is free (local HDFS):
    input::upload_tera_dataset(&env2.store, "res", "hdfs-out", sizing.parts, sizing.part_bytes, 4);
    let up = stocator::workloads::copy::run(&mut env2, "hdfs-out", "final");
    assert!(up.is_valid());
    let total = gen_time + up.runtime;
    println!(
        "via HDFS (gen {:.1}s + upload {:.1}s): {:>7.1}s, {} REST ops",
        gen_time.as_secs_f64(),
        up.runtime.as_secs_f64(),
        total.as_secs_f64(),
        up.ops.total()
    );
    println!(
        "\nthe workaround is x{:.1} slower than writing directly with Stocator\n(and still pays the REST ops of a full copy)",
        total.as_secs_f64() / direct.runtime.as_secs_f64()
    );
    assert!(total > direct.runtime);
}
