//! Quickstart: the paper's §2.3 motivating example — one Spark job writing
//! one object — run on all three connectors, showing why Stocator needs 8
//! REST operations where S3a needs ~100; then the streaming I/O API in
//! miniature: a chunked write that is still ONE PUT, a range read that
//! moves only the requested bytes, and the `--readahead` prefetch window
//! coalescing many small reads into a handful of ranged GETs; finally the
//! HTTP gateway — the same job over a real socket, with identical REST
//! accounting.
//!
//!   cargo run --release --example quickstart

use stocator::connectors::Stocator;
use stocator::fs::{FileSystem, FsInputStream, FsOutputStream, OpCtx, Path};
use stocator::gateway::{GatewayServer, HttpBackend};
use stocator::harness::tables::render_table2;
use stocator::harness::traces::table1_trace;
use stocator::metrics::OpKind;
use stocator::objectstore::backend::ShardedMemBackend;
use stocator::objectstore::{ObjectStore, StoreConfig};
use stocator::simclock::SimInstant;
use std::sync::Arc;

fn main() {
    println!("== Table 1 — the same program on HDFS (file operations) ==");
    for (i, line) in table1_trace().iter().enumerate() {
        println!("  {:>2}. {line}", i + 1);
    }
    println!();
    print!("{}", render_table2());
    println!();
    println!("Stocator writes each part directly to its final, attempt-qualified");
    println!("name; no COPY, no DELETE, no commit-time listings (paper §3.1).");

    println!();
    println!("== Streaming I/O: FsOutputStream / FsInputStream ==");
    let store = ObjectStore::new(StoreConfig::instant_strong());
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let path = Path::parse("swift2d://res/logs/part-00000").unwrap();

    // Stream the object in three chunks — chunked transfer encoding, so
    // the store still sees exactly ONE PUT.
    let mut out = fs.create(&path, true, &mut ctx).unwrap();
    for chunk in [&b"alpha "[..], b"beta ", b"gamma"] {
        out.write(chunk, &mut ctx).unwrap();
    }
    out.close(&mut ctx).unwrap();

    // Range-read the middle without fetching the whole object (and, being
    // Stocator, without any HEAD before the GET — §3.4).
    let mut input = fs.open(&path, &mut ctx).unwrap();
    let mid = input.read_range(6, 5, &mut ctx).unwrap();
    assert_eq!(&mid, b"beta ");

    let counts = store.counters();
    println!("  wrote 3 chunks as one object : PUT ops = {}", counts.get(OpKind::PutObject));
    println!("  read_range(6, 5)             -> {:?}", String::from_utf8_lossy(&mid));
    println!(
        "  GET ops = {}, HEAD ops = {}, bytes over the wire = {}",
        counts.get(OpKind::GetObject),
        counts.get(OpKind::HeadObject),
        counts.bytes_read,
    );
    println!("  (one of the PUTs is the container create; no HEAD before GET)");

    println!();
    println!("== Readahead: small reads coalesce into window fills ==");
    // The same store semantics with a 4 KiB prefetch window (the CLI
    // spelling is `--readahead 4096`; `off` restores one GET per read).
    let store = ObjectStore::new(StoreConfig {
        readahead: 4096,
        ..StoreConfig::instant_strong()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let path = Path::parse("swift2d://res/logs/records").unwrap();
    fs.write_all(&path, vec![42u8; 16 * 1024], true, &mut ctx).unwrap();
    let before = store.counters();
    let mut input = fs.open(&path, &mut ctx).unwrap();
    let mut total = 0usize;
    for off in (0..16 * 1024u64).step_by(256) {
        total += input.read_range(off, 256, &mut ctx).unwrap().len();
    }
    let reads = 16 * 1024 / 256;
    let d = store.counters().since(&before);
    println!("  {reads} sequential 256-byte reads of a 16 KiB object:");
    println!(
        "  GET ops = {} (window 4 KiB, grows on sequential reads), bytes = {total}",
        d.get(OpKind::GetObject),
    );
    println!("  with --readahead off the same loop issues {reads} GETs");
    assert_eq!(total, 16 * 1024);
    assert!(d.get(OpKind::GetObject) * 4 <= reads);

    println!();
    println!("== Transient faults: one flaky PUT, the connector recovers ==");
    // CLI spelling: --faults put:logs/@1 --retries 2. The first PUT under
    // logs/ gets a 503; Stocator cannot resume a chunked transfer, so the
    // retry re-sends the WHOLE object from offset 0 — and the job output
    // is byte-identical to a fault-free run.
    use stocator::objectstore::{FaultSpec, RetryPolicy};
    let store = ObjectStore::new(StoreConfig {
        faults: FaultSpec::parse("put:logs/@1").unwrap(),
        retry: RetryPolicy::with_retries(2),
        ..StoreConfig::instant_strong()
    });
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let path = Path::parse("swift2d://res/logs/part-00000").unwrap();
    let before = store.counters();
    fs.write_all(&path, b"alpha beta gamma".to_vec(), true, &mut ctx).unwrap();
    let d = store.counters().since(&before);
    let data = fs.read_all(&path, &mut ctx).unwrap();
    println!(
        "  PUT ops = {} (1 failed + 1 retry), wire bytes = {} (the 503 burned a full send)",
        d.get(OpKind::PutObject),
        d.bytes_written,
    );
    println!("  read back: {:?} — identical output despite the fault", String::from_utf8_lossy(&data));
    assert_eq!(d.get(OpKind::PutObject), 2);
    assert_eq!(d.bytes_written, 2 * 16);
    assert_eq!(&*data, b"alpha beta gamma");
    println!();
    println!("  (--multipart-ttl SECS additionally sweeps multipart uploads stranded");
    println!("   by crashed fast-upload writers; see Table 8's stranded-bytes addendum)");

    println!();
    println!("== HTTP gateway: the same job over a real socket ==");
    // Spawn an in-process gateway on an ephemeral port (the CLI spelling
    // is `stocator-sim serve`), then run the 3-chunk streaming write and
    // the range read THROUGH it with `--backend http:ADDR` semantics.
    let gateway = GatewayServer::bind("127.0.0.1:0", Arc::new(ShardedMemBackend::new(4)))
        .expect("bind gateway on an ephemeral port")
        .spawn();
    let addr = gateway.addr();
    let remote = HttpBackend::connect(&addr.to_string(), None).expect("connect to gateway");
    let store = ObjectStore::with_backend(StoreConfig::instant_strong(), Box::new(remote));
    store.create_container("res", SimInstant::EPOCH).0.unwrap();
    let fs = Stocator::with_defaults(store.clone());
    let mut ctx = OpCtx::new(SimInstant::EPOCH);
    let path = Path::parse("swift2d://res/logs/part-00000").unwrap();
    let mut out = fs.create(&path, true, &mut ctx).unwrap();
    for chunk in [&b"alpha "[..], b"beta ", b"gamma"] {
        out.write(chunk, &mut ctx).unwrap();
    }
    out.close(&mut ctx).unwrap();
    let mut input = fs.open(&path, &mut ctx).unwrap();
    let mid = input.read_range(6, 5, &mut ctx).unwrap();
    assert_eq!(&mid, b"beta ");
    let counts = store.counters();
    println!("  gateway listening on http://{addr} (backend: sharded-mem)");
    println!(
        "  same 3-chunk write + range read over the wire: PUT = {}, GET = {}, HEAD = {}",
        counts.get(OpKind::PutObject),
        counts.get(OpKind::GetObject),
        counts.get(OpKind::HeadObject),
    );
    println!("  REST accounting is byte-identical to the in-process run above —");
    println!("  the front end owns op counts; the wire only moves the bytes.");
    assert_eq!(counts.get(OpKind::PutObject), 2, "container create + ONE PUT");
    assert_eq!(counts.get(OpKind::GetObject), 1);
    assert_eq!(counts.get(OpKind::HeadObject), 0, "Stocator never HEADs before GET");
    println!();
    println!("  (serve it yourself:  stocator-sim serve --backend sharded --addr 127.0.0.1:7070");
    println!("   then:               stocator-sim run --workload teragen --scenario stocator \\");
    println!("                         --small --backend http:127.0.0.1:7070)");
}
