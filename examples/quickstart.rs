//! Quickstart: the paper's §2.3 motivating example — one Spark job writing
//! one object — run on all three connectors, showing why Stocator needs 8
//! REST operations where S3a needs ~100.
//!
//!   cargo run --release --example quickstart

use stocator::harness::tables::render_table2;
use stocator::harness::traces::table1_trace;

fn main() {
    println!("== Table 1 — the same program on HDFS (file operations) ==");
    for (i, line) in table1_trace().iter().enumerate() {
        println!("  {:>2}. {line}", i + 1);
    }
    println!();
    print!("{}", render_table2());
    println!();
    println!("Stocator writes each part directly to its final, attempt-qualified");
    println!("name; no COPY, no DELETE, no commit-time listings (paper §3.1).");
}
