//! Fault tolerance + speculative execution (paper §3.5, Table 3): task 2
//! is executed three times; Stocator keeps every attempt under a distinct
//! name, aborts delete the losers by *constructed* name, and the read path
//! returns exactly one part per task either way.
//!
//!   cargo run --release --example speculation_faults

use stocator::harness::traces::table3_trace;

fn main() {
    println!("== Table 3, lines 1-3 + 8-9: every task runs once ==");
    let (trace, names) = table3_trace(0, false);
    for l in &trace {
        println!("  {l}");
    }
    println!("  final objects: {names:?}\n");

    println!("== Table 3, lines 1-9: 3 attempts of task 2, Spark cleans up ==");
    let (trace, names) = table3_trace(2, true);
    for l in trace.iter().filter(|l| l.contains("PUT") || l.contains("DELETE")) {
        println!("  {l}");
    }
    println!("  final objects: {names:?}\n");

    println!("== Table 3, lines 1-5 + 8-9: duplicates remain (no cleanup) ==");
    let (_, names) = table3_trace(2, false);
    println!("  final objects: {names:?}");
    println!("  (the read path dedups by most-data; see eventual_consistency)");
}
